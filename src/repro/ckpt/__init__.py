from repro.ckpt.checkpoint import (CheckpointManager, save_checkpoint,
                                   restore_checkpoint,
                                   save_sharded_checkpoint,
                                   restore_sharded_checkpoint, latest_step,
                                   list_steps)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "save_sharded_checkpoint", "restore_sharded_checkpoint",
           "latest_step", "list_steps"]
