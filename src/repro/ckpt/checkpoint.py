"""Sharded checkpointing: manifests, async save, addressable-shard formats.

Two on-disk formats share one directory layout and one manifest commit point:

format 1 — host-local full arrays (the original single-host container path):
    <dir>/step_000100/
        manifest.json        — step, keys (global shape/dtype/sha), extra
        host0000.npz         — this host's FULL copy of every leaf

format 2 — addressable shards (the real multi-host path):
    <dir>/step_000100/
        host0000.npz         — ONLY the shards addressable on host 0
        shards_host0000.json — per-shard records: key, npz entry, index
                               ([start, stop) per dim) and sha256 checksum
        host0001.npz / shards_host0001.json / ...
        manifest.json        — tree structure + GLOBAL shapes, written by
                               process 0 only after every host's shard
                               manifest landed (a filesystem barrier, so the
                               manifest stays the atomic commit record and
                               ``list_steps`` never sees a partial save)

Design points:
  - save is ASYNC-capable (``CheckpointManager``): leaves are snapshotted to
    host memory synchronously, file writes happen in a background thread;
  - save writes only ``arr.addressable_shards`` with ``replica_id == 0`` —
    each global shard is written exactly once across the fleet, replicated
    leaves are written by whichever host owns replica 0;
  - restore is ELASTIC: ``restore_sharded_checkpoint`` assembles every leaf
    against a TARGET sharding via ``jax.make_array_from_single_device_arrays``
    — the target mesh may have a different shape, host count, or axis split
    than the one that saved (N hosts -> M hosts re-mesh). ``shardings=None``
    assembles plain host-local arrays (the degenerate 1-host re-mesh);
  - integrity: every restore path verifies checksums — format 1 per leaf,
    format 2 per shard — and corruption errors NAME THE FILE so the operator
    knows which host's write is bad;
  - QTensor leaves round-trip component-wise (packed/scale/zero are separate
    entries, so the component-level shardings from
    ``dist.sharding.param_specs`` apply to save and restore alike).
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro import obs
from repro.core.quant import QTensor

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "save_sharded_checkpoint", "restore_sharded_checkpoint",
           "latest_step", "list_steps"]

_SEP = "/"


def _flatten(tree, path=()):
    """Yield (path, leaf) with QTensor exploded to components and None kept
    as a sentinel. Leaves are NOT converted — they may be sharded jax arrays
    whose full value is not addressable on this host."""
    if isinstance(tree, QTensor):
        yield path + ("__qt_packed",), tree.packed
        yield path + ("__qt_scale",), tree.scale
        yield path + ("__qt_zero",), tree.zero
        yield path + ("__qt_meta",), np.array(
            [tree.bits, tree.group_size] + list(tree.shape), np.int64)
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (f"__{i}",))
    elif tree is None:
        yield path + ("__none",), np.zeros((), np.int8)
    else:
        yield path, tree


def _flatten_numpy(tree) -> dict:
    """Flat key -> full numpy value (format 1: every leaf fully addressable)."""
    return {_SEP.join(p): np.asarray(v) for p, v in _flatten(tree)}


def _unflatten(flat: dict):
    """Rebuild nested dict/tuple/QTensor tree from flat 'a/b/c' keys."""
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__none" in node:
            return None
        if "__qt_meta" in node:
            meta = np.asarray(node["__qt_meta"])
            bits, group = int(meta[0]), int(meta[1])
            shape = tuple(int(x) for x in meta[2:])
            return QTensor(jax.numpy.asarray(node["__qt_packed"]),
                           jax.numpy.asarray(node["__qt_scale"]),
                           jax.numpy.asarray(node["__qt_zero"]),
                           bits, group, shape)
        if node and all(k.startswith("__") and k[2:].isdigit() for k in node):
            return tuple(rebuild(node[f"__{i}"]) for i in range(len(node)))
        return {k: rebuild(v) for k, v in node.items()}

    def to_device(x):
        # restored leaves must be jax arrays (numpy leaves break tracer
        # indexing, e.g. stacked-weight slicing inside the jitted search)
        return jax.numpy.asarray(x) if isinstance(x, np.ndarray) else x

    return jax.tree.map(to_device, rebuild(root),
                        is_leaf=lambda x: isinstance(x, np.ndarray) or x is None)


def _flatten_shardings(tree, path=()):
    """Flat key -> target sharding, mirroring ``_flatten``'s key scheme.

    The spec tree mirrors the SAVED tree: QTensor nodes may carry
    component-wise shardings (``dist.sharding.param_specs``); ``__qt_meta``
    is host metadata and always restores locally. A plain (non-QTensor-aware)
    sharding at a QTensor position applies to all three components only when
    identical treatment is valid — we require component-wise trees and fall
    back to local assembly otherwise."""
    if tree is None:
        return {}
    out: dict = {}
    if isinstance(tree, QTensor):
        out[_SEP.join(path + ("__qt_packed",))] = tree.packed
        out[_SEP.join(path + ("__qt_scale",))] = tree.scale
        out[_SEP.join(path + ("__qt_zero",))] = tree.zero
        return out
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_shardings(v, path + (k,)))
        return out
    if isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten_shardings(v, path + (f"__{i}",)))
        return out
    out[_SEP.join(path)] = tree
    return out


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def _publish_npz(directory: pathlib.Path, name: str, flat: dict):
    tmp = directory / f".tmp_{name}"                  # np.savez appends .npz
    np.savez(tmp, **flat)                             # unless it's present
    tmp_npz = directory / f".tmp_{name}.npz"
    obs.counter("ckpt_bytes_written_total",
                "Checkpoint shard/file bytes published to disk").inc(
        tmp_npz.stat().st_size)
    tmp_npz.rename(directory / f"{name}.npz")


def _publish_json(path: pathlib.Path, obj):
    tmp = path.with_name("." + path.name + ".tmp")
    tmp.write_text(json.dumps(obj))
    tmp.rename(path)


# ---------------------------------------------------------------------------
# format 1: host-local full arrays
# ---------------------------------------------------------------------------

def save_checkpoint(directory, step: int, tree, *, host_id: int = 0,
                    extra: Optional[dict] = None, verify: bool = True):
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten_numpy(tree)
    _write_full(d, step, flat, host_id=host_id, extra=extra, verify=verify)
    return d


def _write_full(d: pathlib.Path, step: int, flat: dict, *, host_id: int,
                extra: Optional[dict], verify: bool):
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                     **({"sha": _checksum(v)} if verify else {})}
                 for k, v in flat.items()},
        "extra": extra or {},
        "format": 1,
    }
    _publish_npz(d, f"host{host_id:04d}", flat)
    _publish_json(d / "manifest.json", manifest)


def restore_checkpoint(directory, step: Optional[int] = None, *, host_id: int = 0,
                       verify: bool = True):
    """Returns (tree, manifest). Elastic: caller re-shards with
    jax.device_put(tree, shardings) for whatever mesh is now alive. For
    format-2 (addressable-shard) checkpoints use
    ``restore_sharded_checkpoint`` — calling this on one restores the full
    tree host-locally."""
    base = pathlib.Path(directory)
    step = _resolve_step(base, step)
    d = base / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest.get("format", 1) == 2:
        tree = restore_sharded_checkpoint(directory, step, None,
                                          verify=verify)[0]
        return tree, manifest
    shard_file = d / f"host{host_id:04d}.npz"
    try:
        with np.load(shard_file) as z:
            flat = {k: z[k] for k in z.files}
    except Exception as e:  # zip CRC / truncation surfaces before checksums
        raise IOError(f"checkpoint corruption in {shard_file}: "
                      f"unreadable shard file ({e})") from e
    if verify:
        for k, meta in manifest["keys"].items():
            if k not in flat:
                raise IOError(f"checkpoint corruption in {shard_file}: "
                              f"leaf {k!r} missing from shard file")
            if "sha" in meta and _checksum(flat[k]) != meta["sha"]:
                raise IOError(f"checkpoint corruption in {shard_file}: "
                              f"leaf {k!r} fails its manifest checksum")
    return _unflatten(flat), manifest


def _resolve_step(base: pathlib.Path, step: Optional[int]) -> int:
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {base}")
    return step


# ---------------------------------------------------------------------------
# format 2: addressable shards (the multi-host path)
# ---------------------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype string, including the ml_dtypes extension
    types (bfloat16, float8_*) numpy itself cannot parse."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency, always present
        return np.dtype(getattr(ml_dtypes, name))


def _to_bytes(data: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a shard's payload. npz round-trips extension
    dtypes (bfloat16 et al) as raw void and the typed assemble() assignment
    then has no cast — so shards are stored as bytes and viewed back through
    the manifest dtype on read."""
    return np.ascontiguousarray(data).reshape(-1).view(np.uint8)


def _from_bytes(raw: np.ndarray, dtype: np.dtype, shape) -> np.ndarray:
    return raw.view(dtype).reshape(shape)


def _normalize_index(index, shape) -> list:
    """tuple-of-slices -> [[start, stop], ...] resolved against ``shape``."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        if sl.step not in (None, 1):
            raise ValueError(f"strided shard index unsupported: {sl}")
        out.append([start, stop])
    return out


def _prepare_shards(tree):
    """Synchronous device->host snapshot of this process's shard of every
    leaf. Returns (records, flat_arrays, keys_meta):
      records     — [{key, npz, index, sha}] for this host's shard manifest
      flat_arrays — npz entry name -> numpy shard data
      keys_meta   — flat key -> {shape, dtype} GLOBAL metadata (identical on
                    every host; process 0's copy becomes the manifest)
    Only shards with replica_id == 0 are kept, so each global shard is
    written exactly once across all hosts."""
    pid = jax.process_index()
    records, flat_arrays, keys_meta = [], {}, {}
    for path, leaf in _flatten(tree):
        key = _SEP.join(path)
        if isinstance(leaf, jax.Array):
            keys_meta[key] = {"shape": list(leaf.shape),
                              "dtype": str(leaf.dtype)}
            for n, sh in enumerate(leaf.addressable_shards):
                if sh.replica_id != 0:
                    continue
                data = _to_bytes(np.asarray(sh.data))
                npz_key = f"{key}#{n}"
                records.append({
                    "key": key, "npz": npz_key,
                    "index": _normalize_index(sh.index, leaf.shape),
                    "sha": _checksum(data),
                })
                flat_arrays[npz_key] = data
        else:  # host-side value (e.g. __qt_meta), identical everywhere
            data = np.asarray(leaf)
            keys_meta[key] = {"shape": list(data.shape),
                              "dtype": str(data.dtype)}
            if pid == 0:
                npz_key = f"{key}#0"
                raw = _to_bytes(data)
                records.append({
                    "key": key, "npz": npz_key,
                    "index": [[0, d] for d in data.shape],
                    "sha": _checksum(raw),
                })
                flat_arrays[npz_key] = raw
    return records, flat_arrays, keys_meta


def _write_shards(d: pathlib.Path, step: int, prepared, *, extra, timeout):
    records, flat_arrays, keys_meta = prepared
    pid, n_hosts = jax.process_index(), jax.process_count()
    # a crashed earlier attempt at this step (no manifest.json committed)
    # may have left THIS host's files behind; remove them first so process
    # 0's filesystem barrier below cannot count a stale shard manifest as
    # this attempt's. (Each host cleans only its own files — cross-host
    # deletes would race with a peer's in-flight write. A peer that never
    # restarts at all can still leave a stale manifest; the commit record
    # staying absent until every host re-publishes bounds the damage to
    # the uncommitted step.)
    if not (d / "manifest.json").exists():
        for stale in (d / f"host{pid:04d}.npz",
                      d / f"shards_host{pid:04d}.json"):
            try:
                stale.unlink()
            except FileNotFoundError:
                pass
    _publish_npz(d, f"host{pid:04d}", flat_arrays)
    _publish_json(d / f"shards_host{pid:04d}.json",
                  {"host": pid, "shards": records})
    if pid != 0:
        return
    # filesystem barrier: the manifest is the commit record, so it must not
    # land before every host's shard manifest has (no collective here — this
    # may run on the CheckpointManager thread, where issuing collectives
    # could interleave with the main thread's and deadlock the fleet)
    deadline = time.monotonic() + timeout
    while len(list(d.glob("shards_host*.json"))) < n_hosts:
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"step {step}: only "
                f"{len(list(d.glob('shards_host*.json')))}/{n_hosts} host "
                f"shard manifests landed within {timeout}s")
        time.sleep(0.05)
    _publish_json(d / "manifest.json", {
        "step": step, "keys": keys_meta, "extra": extra or {},
        "format": 2, "hosts": n_hosts,
    })


def save_sharded_checkpoint(directory, step: int, tree, *,
                            extra: Optional[dict] = None,
                            timeout: float = 120.0):
    """Addressable-shard save: every host writes ONLY its local shards plus a
    shard manifest (index + checksum per shard); process 0 publishes the
    global manifest once all hosts' shard manifests exist. Synchronous; the
    async wrapper is ``CheckpointManager(sharded=True)``."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    _write_shards(d, step, _prepare_shards(tree), extra=extra, timeout=timeout)
    return d


class _ShardReader:
    """Lazy, checksum-verifying reader over every host's saved shards."""

    def __init__(self, d: pathlib.Path, n_hosts: int, verify: bool):
        self.d = d
        self.verify = verify
        self.by_key: dict = {}
        self._npz: dict = {}
        for h in range(n_hosts):
            mf = d / f"shards_host{h:04d}.json"
            if not mf.exists():
                raise IOError(f"checkpoint corruption in {d}: shard manifest "
                              f"{mf.name} missing (host {h} never wrote)")
            for rec in json.loads(mf.read_text())["shards"]:
                self.by_key.setdefault(rec["key"], []).append((h, rec))

    def shard(self, host: int, rec: dict, dtype: np.dtype) -> np.ndarray:
        f = self.d / f"host{host:04d}.npz"
        if host not in self._npz:
            if not f.exists():
                raise IOError(f"checkpoint corruption in {f}: shard file "
                              f"missing")
            self._npz[host] = np.load(f)
        try:
            raw = self._npz[host][rec["npz"]]
        except Exception as e:
            raise IOError(f"checkpoint corruption in {f}: shard "
                          f"{rec['npz']!r} unreadable ({e})") from e
        if self.verify and _checksum(raw) != rec["sha"]:
            raise IOError(f"checkpoint corruption in {f}: shard "
                          f"{rec['npz']!r} fails its shard-manifest checksum")
        shape = tuple(b - a for a, b in rec["index"])
        return _from_bytes(raw, dtype, shape)

    def close(self):
        for z in self._npz.values():
            z.close()

    def assemble(self, key: str, shape, dtype, index=None) -> np.ndarray:
        """Materialize ``arr[index]`` (or the full array) for a saved leaf by
        stitching overlapping saved shards; verifies full coverage."""
        if index is None:
            index = [[0, d] for d in shape]
        tgt_shape = tuple(b - a for a, b in index)
        out = np.zeros(tgt_shape, dtype=dtype)
        covered = 0
        for host, rec in self.by_key.get(key, ()):
            ov = []  # overlap box in global coords
            for (ta, tb), (sa, sb) in zip(index, rec["index"]):
                lo, hi = max(ta, sa), min(tb, sb)
                if lo >= hi:
                    ov = None
                    break
                ov.append((lo, hi))
            if ov is None:
                continue
            data = self.shard(host, rec, dtype)
            src = tuple(slice(lo - sa, hi - sa)
                        for (lo, hi), (sa, _) in zip(ov, rec["index"]))
            dst = tuple(slice(lo - ta, hi - ta)
                        for (lo, hi), (ta, _) in zip(ov, index))
            out[dst] = data[src]
            covered += int(np.prod([hi - lo for lo, hi in ov])) if ov else 1
        want = int(np.prod(tgt_shape)) if tgt_shape else 1
        if covered != want:
            raise IOError(
                f"checkpoint corruption in {self.d}: leaf {key!r} has "
                f"{covered}/{want} elements covered by saved shards for "
                f"index {index} (missing or overlapping host shard files)")
        return out


def restore_sharded_checkpoint(directory, step: Optional[int] = None,
                               shardings=None, *, verify: bool = True):
    """Elastic restore of a format-2 checkpoint. Returns (tree, manifest).

    ``shardings`` is a tree of target ``jax.sharding.Sharding`` leaves
    mirroring the saved tree (QTensor positions may carry component-wise
    QTensor spec nodes, as built by ``dist.sharding.param_specs`` +
    ``to_shardings``). The target mesh may differ arbitrarily from the saving
    mesh — each target shard is assembled from whichever hosts' saved shards
    overlap it and placed via ``jax.make_array_from_single_device_arrays``.
    ``shardings=None`` (or per-leaf None) assembles plain host-local arrays.
    """
    base = pathlib.Path(directory)
    step = _resolve_step(base, step)
    d = base / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest.get("format", 1) != 2:
        tree, manifest = restore_checkpoint(directory, step, verify=verify)
        if shardings is not None:
            flat_s = _flatten_shardings(shardings)
            flat = {k: (jax.device_put(v, flat_s[k])
                        if flat_s.get(k) is not None else v)
                    for k, v in _flatten_numpy(tree).items()}
            tree = _unflatten(flat)
        return tree, manifest
    reader = _ShardReader(d, int(manifest.get("hosts", 1)), verify)
    flat_s = _flatten_shardings(shardings)
    try:
        flat = {}
        for key, meta in manifest["keys"].items():
            shape = tuple(meta["shape"])
            dtype = _np_dtype(meta["dtype"])
            target = flat_s.get(key)
            if target is None:
                flat[key] = reader.assemble(key, shape, dtype)
            else:
                idx_map = target.addressable_devices_indices_map(shape)
                bufs = [jax.device_put(
                            reader.assemble(key, shape, dtype,
                                            _normalize_index(idx, shape)), dev)
                        for dev, idx in idx_map.items()]
                flat[key] = jax.make_array_from_single_device_arrays(
                    shape, target, bufs)
    finally:
        reader.close()
    return _unflatten(flat), manifest


# ---------------------------------------------------------------------------
# directory queries + async manager
# ---------------------------------------------------------------------------

def list_steps(directory) -> list:
    """Steps with a published manifest, ascending (partial saves excluded)."""
    base = pathlib.Path(directory)
    if not base.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                  if (p / "manifest.json").exists())


def latest_step(directory) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def _host_id_of(name: str) -> int:
    """Host id embedded in a shard filename (``host0003.npz`` /
    ``shards_host0003.json`` -> 3); -1 if the name doesn't parse."""
    stem = name.split(".")[0]
    digits = stem[len("shards_host"):] if stem.startswith("shards_host") \
        else stem[len("host"):]
    try:
        return int(digits)
    except ValueError:
        return -1


class CheckpointManager:
    """Async save + retention. ``save()`` snapshots leaves to host memory
    synchronously (donation-safe) and returns; file writes run on a
    background thread, at most one in flight (``wait()`` joins).

    ``sharded=True`` switches to the format-2 addressable-shard writer: every
    process must run ``save()``/``wait()`` at the same step, and ``restore``
    takes target shardings for the elastic re-mesh. Retention (gc) runs in
    PARALLEL in that mode: each host unlinks its own shard files (process 0
    uncommits the manifest first and sweeps shards of shrunk-away hosts), so
    gc cost per host stays constant as the mesh grows."""

    def __init__(self, directory, keep: int = 3, *, sharded: bool = False):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self.sharded = sharded
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra=None):
        self.wait()
        d = self.dir / f"step_{step:08d}"
        d.mkdir(parents=True, exist_ok=True)
        if self.sharded:
            prepared = _prepare_shards(tree)   # sync: donation-safe snapshot

            def _write():
                _write_shards(d, step, prepared, extra=extra, timeout=120.0)
        else:
            flat = _flatten_numpy(tree)        # sync: QTensor components too

            def _write():
                _write_full(d, step, flat, host_id=0, extra=extra,
                            verify=True)

        def _work():
            try:
                with obs.trace_span("ckpt.save", step=step,
                                    sharded=self.sharded,
                                    hist=obs.histogram(
                                        "ckpt_save_seconds",
                                        "Checkpoint write latency")):
                    _write()
                self._gc()
            except BaseException as e:  # noqa: BLE001 — re-raised by wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        """Join the in-flight save. A failure on the writer thread (shard
        timeout, unwritable dir) re-raises HERE — callers that treat a
        returned wait() as "the checkpoint is durable" (run_resilient,
        PreemptionGuard.drain) must not be lied to by a dead daemon."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise IOError(f"async checkpoint save failed: {e}") from e

    def restore(self, step=None, shardings=None, *, verify: bool = True):
        """Checksum-verifying restore (the async path verifies exactly like
        the direct functions — corruption raises IOError naming the file)."""
        self.wait()  # an in-flight async save must land before we read
        with obs.trace_span("ckpt.restore", hist=obs.histogram(
                "ckpt_restore_seconds", "Checkpoint restore latency")):
            if self.sharded or shardings is not None:
                return restore_sharded_checkpoint(self.dir, step, shardings,
                                                  verify=verify)
            return restore_checkpoint(self.dir, step, verify=verify)

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*"))
        drop = steps[:-self.keep]
        if drop:
            obs.counter("ckpt_gc_sweeps_total",
                        "Retention sweeps that removed old steps").inc()
        if not self.sharded:
            for p in drop:
                for f in p.iterdir():
                    f.unlink()
                p.rmdir()
            return
        # Sharded retention runs on EVERY host's writer thread: each host
        # unlinks its own shard files, so gc cost per host is constant
        # instead of process 0 serially unlinking O(hosts) files per step.
        pid = jax.process_index()
        nproc = jax.process_count()
        for p in drop:
            if pid == 0:
                # uncommit FIRST: list_steps/restore key on the manifest, so
                # once it is gone no reader can race the per-host unlinks
                # below into a partial restore
                (p / "manifest.json").unlink(missing_ok=True)
            (p / f"host{pid:04d}.npz").unlink(missing_ok=True)
            (p / f"shards_host{pid:04d}.json").unlink(missing_ok=True)
            if pid == 0:
                # sweep shards of host ids beyond the current topology (a
                # save from a larger mesh leaves files no live process owns)
                for f in p.glob("*host*.npz"):
                    if _host_id_of(f.name) >= nproc:
                        f.unlink(missing_ok=True)
                for f in p.glob("shards_host*.json"):
                    if _host_id_of(f.name) >= nproc:
                        f.unlink(missing_ok=True)
            try:
                p.rmdir()   # whichever host unlinks last wins the rmdir;
            except OSError:  # still-populated (peer mid-gc) is fine — the
                pass         # directory is retried on the next gc cycle
