"""Sharded checkpointing with manifest, async save, and elastic re-mesh restore.

Layout:
    <dir>/step_000100/
        manifest.json        — step, config hash, tree structure, global shapes
        host0000.npz         — this host's shard of every leaf (flat key -> array)

Design points (DESIGN.md §5):
  - save is ASYNC (background thread) — training continues while the previous
    step serializes; ``wait()`` joins before the next save or exit;
  - restore is ELASTIC: the manifest records global logical shapes, restore
    re-shards onto ANY mesh/host topology (leaves are saved as full arrays
    per host here — single-host container — but the addressable-shard path is
    the same code with a gather swapped in);
  - integrity: manifest carries per-leaf checksums; restore verifies them;
  - QTensor leaves round-trip (flattened to their component arrays).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import threading
from typing import Optional

import jax
import numpy as np

from repro.core.quant import QTensor

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "latest_step", "list_steps"]

_SEP = "/"


def _flatten(tree, path=()):
    if isinstance(tree, QTensor):
        yield path + ("__qt_packed",), np.asarray(tree.packed)
        yield path + ("__qt_scale",), np.asarray(tree.scale)
        yield path + ("__qt_zero",), np.asarray(tree.zero)
        yield path + ("__qt_meta",), np.array(
            [tree.bits, tree.group_size] + list(tree.shape), np.int64)
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (k,))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (f"__{i}",))
    elif tree is None:
        yield path + ("__none",), np.zeros((), np.int8)
    else:
        yield path, np.asarray(tree)


def _unflatten(flat: dict):
    """Rebuild nested dict/tuple/QTensor tree from flat 'a/b/c' keys."""
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__none" in node:
            return None
        if "__qt_meta" in node:
            meta = node["__qt_meta"]
            bits, group = int(meta[0]), int(meta[1])
            shape = tuple(int(x) for x in meta[2:])
            return QTensor(jax.numpy.asarray(node["__qt_packed"]),
                           jax.numpy.asarray(node["__qt_scale"]),
                           jax.numpy.asarray(node["__qt_zero"]),
                           bits, group, shape)
        if node and all(k.startswith("__") and k[2:].isdigit() for k in node):
            return tuple(rebuild(node[f"__{i}"]) for i in range(len(node)))
        return {k: rebuild(v) for k, v in node.items()}

    def to_device(x):
        # restored leaves must be jax arrays (numpy leaves break tracer
        # indexing, e.g. stacked-weight slicing inside the jitted search)
        return jax.numpy.asarray(x) if isinstance(x, np.ndarray) else x

    return jax.tree.map(to_device, rebuild(root),
                        is_leaf=lambda x: isinstance(x, np.ndarray) or x is None)


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save_checkpoint(directory, step: int, tree, *, host_id: int = 0,
                    extra: Optional[dict] = None, verify: bool = True):
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = {_SEP.join(path): np.asarray(v) for path, v in _flatten(tree)}
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                     **({"sha": _checksum(v)} if verify else {})}
                 for k, v in flat.items()},
        "extra": extra or {},
        "format": 1,
    }
    tmp = d / f".tmp_host{host_id:04d}.npz"            # np.savez appends .npz
    np.savez(tmp, **flat)                              # unless it's present
    tmp.rename(d / f"host{host_id:04d}.npz")           # atomic publish
    (d / "manifest.json").write_text(json.dumps(manifest))
    return d


def restore_checkpoint(directory, step: Optional[int] = None, *, host_id: int = 0,
                       verify: bool = True):
    """Returns (tree, manifest). Elastic: caller re-shards with
    jax.device_put(tree, shardings) for whatever mesh is now alive."""
    base = pathlib.Path(directory)
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {base}")
    d = base / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / f"host{host_id:04d}.npz") as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["keys"].items():
            if "sha" in meta and _checksum(flat[k]) != meta["sha"]:
                raise IOError(f"checkpoint corruption in leaf {k!r}")
    return _unflatten(flat), manifest


def list_steps(directory) -> list:
    """Steps with a published manifest, ascending (partial saves excluded)."""
    base = pathlib.Path(directory)
    if not base.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                  if (p / "manifest.json").exists())


def latest_step(directory) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


class CheckpointManager:
    """Async save + retention. ``save()`` returns immediately; the previous
    save is joined first (at most one in flight)."""

    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.asarray(x), tree,
            is_leaf=lambda x: isinstance(x, QTensor) or x is None)

        def _work():
            save_checkpoint(self.dir, step, host_tree, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, step=None):
        self.wait()  # an in-flight async save must land before we read
        return restore_checkpoint(self.dir, step)

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*"))
        for p in steps[:-self.keep]:
            for f in p.iterdir():
                f.unlink()
            p.rmdir()
