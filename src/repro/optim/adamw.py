"""AdamW + LR schedules + global-norm clipping, from scratch (no optax).

State layout mirrors the param tree (m, v per leaf) so the ZeRO-1 sharding
rules in ``repro.dist.sharding`` can map over it directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = (cfg.min_lr_frac * cfg.lr
           + 0.5 * (1 - cfg.min_lr_frac) * cfg.lr * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr_at


def linear_warmup(cfg: AdamWConfig) -> Callable:
    def lr_at(step):
        return cfg.lr * jnp.minimum(1.0, (step.astype(jnp.float32) + 1) / max(cfg.warmup_steps, 1))
    return lr_at


def adamw_update(params, grads, state, cfg: AdamWConfig, schedule: Callable = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = (schedule or cosine_schedule(cfg))(step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
