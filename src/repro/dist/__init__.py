"""Distributed substrate: sharding rules, fault tolerance, compressed
collectives, and sequence-sharded decode attention.

Layout:
    compat.py      — jax version shims (shard_map location / kwarg drift)
    runtime.py     — jax.distributed bring-up (coordinator/process env vars),
                     psum barrier, device introspection, global placement
    sharding.py    — PartitionSpec trees over the ("data", "model") mesh
    fault.py       — straggler watchdog, checkpoint-restore resilient loop,
                     preemption-signal → checkpoint-and-barrier hook
    collectives.py — group-quantized (compressed) all-reduce + the island
                     search's elite exchange (argmin_allgather scalar race,
                     elite_broadcast state move)
    attention.py   — log-sum-exp partial-softmax merge for sharded KV decode

Everything here is mesh-shape driven and divisibility-aware: a dim that does
not divide its mesh axis falls back to replication instead of failing, so the
same rules serve every assigned architecture (14-head internvl2 included).
"""
from repro.dist.sharding import (ShardingRules, param_specs, opt_state_specs,
                                 cache_specs, data_spec, to_shardings)
from repro.dist.fault import (StepWatchdog, PreemptionGuard, run_resilient,
                              remesh_restore)
from repro.dist.collectives import (compressed_psum, argmin_allgather,
                                    elite_broadcast)
from repro.dist.attention import (partial_decode_attention, merge_partials,
                                  sharded_decode_attention,
                                  sharded_paged_decode_attention)
from repro.dist import runtime

__all__ = [
    "ShardingRules", "param_specs", "opt_state_specs", "cache_specs",
    "data_spec", "to_shardings",
    "StepWatchdog", "PreemptionGuard", "run_resilient", "remesh_restore",
    "compressed_psum", "argmin_allgather", "elite_broadcast",
    "partial_decode_attention", "merge_partials", "sharded_decode_attention",
    "sharded_paged_decode_attention",
    "runtime",
]
