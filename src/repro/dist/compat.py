"""jax version shims.

``shard_map`` has moved twice upstream: it lives at
``jax.experimental.shard_map.shard_map`` with a ``check_rep=`` kwarg on
jax <= 0.4.x, and at ``jax.shard_map`` with the kwarg renamed to
``check_vma=`` on newer releases. Tests and dist code import it from here so
neither spelling leaks into callers.
"""
from __future__ import annotations

import inspect

try:  # newer jax: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# The replication-check kwarg was renamed check_rep -> check_vma.
_params = inspect.signature(_shard_map).parameters
_CHECK_KW = "check_vma" if "check_vma" in _params else (
    "check_rep" if "check_rep" in _params else None)

__all__ = ["shard_map", "make_mesh", "cost_analysis"]


def cost_analysis(compiled) -> dict:
    """Version-stable ``Compiled.cost_analysis()``.

    jax 0.4.x returns a one-element list of per-program dicts; newer jax
    returns the dict directly. Always returns a (possibly empty) dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
    __import__("jax").make_mesh).parameters


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
    """Version-stable ``jax.make_mesh``.

    ``axis_types=`` (and ``jax.sharding.AxisType``) only exist on newer jax;
    older releases treat every axis as Auto anyway, so the flag is simply
    dropped there. Pass ``axis_types="auto"`` to request Auto axes without
    naming the enum (resolved here against the installed jax).
    """
    import jax

    if _MAKE_MESH_HAS_AXIS_TYPES and axis_types is not None:
        if axis_types == "auto":
            axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None,
              **kwargs):
    """Version-stable ``shard_map``.

    Accepts either ``check_vma`` (new spelling) or ``check_rep`` (old
    spelling) and forwards whichever the installed jax understands; the flag
    is dropped entirely on a jax that supports neither.
    """
    check = check_vma if check_vma is not None else check_rep
    if check is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
