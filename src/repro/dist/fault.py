"""Fault tolerance: straggler detection + checkpoint-restore resilient loop.

``run_resilient`` wraps a deterministic step function: on any step failure it
restores the latest checkpoint (or the initial state when none landed yet) and
replays forward. Because the data pipeline is step-indexed and the step
function is pure in (state, step), replay converges to bit-identical state —
the property ``tests/test_substrate.py`` pins with an injected step-7 failure.
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro import obs
from repro.ckpt.checkpoint import list_steps

__all__ = ["StepWatchdog", "PreemptionGuard", "run_resilient",
           "remesh_restore"]


class StepWatchdog:
    """Flags straggler steps: a step slower than ``threshold`` x the median of
    recent healthy steps. Flagged samples are excluded from the baseline so a
    slow patch cannot drag the median up and mask itself.

    Every observation lands in the ``dist_step_seconds`` histogram; trips
    count into ``dist_watchdog_trips_total`` and the rolling median (plus the
    sample count, so "no baseline yet" is distinguishable from "fast") is
    exported as gauges."""

    def __init__(self, threshold: float = 2.0, warmup: int = 5,
                 window: int = 64):
        self.threshold = threshold
        self.warmup = warmup
        self.window = window
        self.flagged = 0
        self._times: list = []
        reg = obs.get_registry()
        self._h_step = reg.histogram(
            "dist_step_seconds", "Observed step durations (all samples)")
        self._c_trips = reg.counter(
            "dist_watchdog_trips_total", "Steps flagged as stragglers")
        self._g_median = reg.gauge(
            "dist_watchdog_median_step_seconds",
            "Rolling median of healthy step durations")
        self._g_samples = reg.gauge(
            "dist_watchdog_samples_seen",
            "Healthy samples in the watchdog baseline")

    def observe(self, step_seconds: float) -> bool:
        """Record one step duration; returns True iff it is a straggler."""
        self._h_step.observe(step_seconds)
        is_straggler = False
        if len(self._times) >= self.warmup:
            baseline = float(np.median(self._times[-self.window:]))
            is_straggler = step_seconds > self.threshold * baseline
        if is_straggler:
            self.flagged += 1
            self._c_trips.inc()
        else:
            self._times.append(step_seconds)
        med = self.median_step
        if med is not None:
            self._g_median.set(med)
        self._g_samples.set(self.samples_seen)
        return is_straggler

    @property
    def samples_seen(self) -> int:
        """Healthy samples recorded so far — report this next to
        ``median_step`` so a pre-warmup ``None`` median reads as "too few
        samples", not silently as "no stragglers"."""
        return len(self._times)

    @property
    def median_step(self) -> Optional[float]:
        if not self._times:
            return None
        return float(np.median(self._times[-self.window:]))

    def stats(self) -> dict:
        """One-line health summary: median (None pre-warmup), the sample
        count that explains it, and trips."""
        return {"median_step": self.median_step,
                "samples_seen": self.samples_seen,
                "warmed_up": self.samples_seen >= self.warmup,
                "flagged": self.flagged}


class PreemptionGuard:
    """Preemption-signal → checkpoint-and-barrier hook.

    Cloud schedulers announce eviction with a signal (SIGTERM on most
    platforms) and a grace window; dying mid-step wastes the window and — on
    a multi-host run — leaves peers hanging in a collective. The guard turns
    the signal into a FLAG checked at step boundaries: ``run_resilient``
    drains to a final checkpoint, then joins a ``runtime.barrier()`` so every
    host exits with the SAME step durably on disk (the next incarnation
    restores it, possibly onto a different mesh via ``remesh_restore``).

    Use as a context manager so the previous handlers are restored (tests,
    nested loops); ``signal.raise_signal`` or a real ``kill`` both work.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.signals = tuple(signals)
        self._flag = threading.Event()
        self._prev: dict = {}

    def __enter__(self):
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._on_signal)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        self._prev.clear()
        return False

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def drain(self, ckpt, step: int, state) -> None:
        """Checkpoint ``state`` at ``step``, join the save, then barrier so
        every host has the step durably written before anyone exits."""
        from repro.dist import runtime
        obs.counter("dist_preemption_drains_total",
                    "Preemption signals drained to a checkpoint").inc()
        with obs.trace_span("dist.preemption_drain", step=step):
            if ckpt is not None:
                ckpt.save(step, state)
                ckpt.wait()
            runtime.barrier("preemption-drain")


def run_resilient(step_fn: Callable, state, n_steps: int, *, ckpt=None,
                  save_every: int = 0, start_step: int = 0, watchdog=None,
                  max_restores: int = 8, preemption: Optional[PreemptionGuard] = None):
    """Run ``state = step_fn(state, step)`` for steps [start_step, n_steps),
    surviving step failures via checkpoint restore.

    ckpt        — a ``CheckpointManager`` (or None: failures re-raise).
    save_every  — checkpoint whenever the completed-step count hits a multiple
                  (manifests record the NEXT step to run, so restore resumes
                  exactly where the save left off).
    watchdog    — optional ``StepWatchdog``; stragglers are logged as events,
                  never fatal.
    max_restores— restart budget; a persistent failure eventually re-raises
                  instead of looping (replay is only safe for transient
                  faults).
    preemption  — optional ``PreemptionGuard``; once its signal fires the
                  loop stops at the NEXT step boundary, checkpoints, joins a
                  cross-host barrier, and returns early with a
                  ("preempted", step) event.

    Returns (final_state, events) where events is a list of tuples:
    ("saved", step) / ("failure", step, msg) / ("restored", step) /
    ("straggler", step, seconds) / ("preempted", step).

    Caveat: with jitted step functions using donated arguments, a failure
    AFTER donation invalidates ``state``'s buffers — restore-from-checkpoint
    handles that too (the restored tree is freshly materialized), but the
    no-checkpoint initial-state fallback assumes the failure preceded
    donation (true for launch/validation-style faults).
    """
    events: list = []
    initial = state
    step = start_step
    restores = 0
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(state, step)
            dt = time.perf_counter() - t0
            if watchdog is not None and watchdog.observe(dt):
                events.append(("straggler", step, dt))
            step += 1
            if preemption is not None and preemption.preempted:
                preemption.drain(ckpt, step, state)
                if ckpt is not None:
                    events.append(("saved", step))
                events.append(("preempted", step))
                return state, events
            if ckpt is not None and save_every and step % save_every == 0:
                ckpt.save(step, state)
                events.append(("saved", step))
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any step fault is recoverable
            events.append(("failure", step, f"{type(e).__name__}: {e}"))
            restores += 1
            if ckpt is None or restores > max_restores:
                raise
            state, step = _restore_newest_intact(ckpt, initial, start_step,
                                                 events)
            events.append(("restored", step))
    if ckpt is not None:
        if save_every and step % save_every != 0:
            ckpt.save(step, state)  # final state: trailing steps survive restart
            events.append(("saved", step))
        ckpt.wait()  # the last async save must land before callers restore
    return state, events


def _restore_newest_intact(ckpt, initial, start_step: int, events: list):
    """Newest checkpoint that actually restores; corrupt ones are skipped
    (a failure that also corrupted the latest save must not end recovery).
    Falls back to the initial state when nothing intact remains."""
    ckpt.wait()
    for s in reversed(list_steps(ckpt.dir)):
        try:
            state, manifest = ckpt.restore(s)
            return state, int(manifest["step"])
        except Exception as e:  # noqa: BLE001 — corrupt shard, keep digging
            events.append(("corrupt_ckpt", s, f"{type(e).__name__}: {e}"))
    return initial, start_step


def remesh_restore(ckpt, shardings=None, step: Optional[int] = None):
    """Elastic restore: load the latest (or given) checkpoint and re-shard it
    onto whatever mesh is now alive — including a DIFFERENT host count than
    the one that saved (N hosts -> M hosts re-mesh).

    ``shardings`` is a tree of target ``jax.sharding.Sharding`` leaves
    matching the state tree (build one with ``dist.sharding.to_shardings``).
    Format-2 (addressable-shard) checkpoints assemble each target shard from
    whichever saved host shards overlap it; format-1 checkpoints load the
    host-local full arrays and ``device_put`` onto the targets. ``None``
    keeps host-local placement — the degenerate remesh onto one device.
    Returns (tree, manifest)."""
    return ckpt.restore(step, shardings)
