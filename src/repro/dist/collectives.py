"""Compressed collectives: group-quantized all-reduce.

Gradient all-reduce is bandwidth-bound on the DCN hop of the multi-pod mesh;
``compressed_psum`` cuts the wire bytes ~4x (8-bit codes vs f32) by reusing
the paper's group-quantization codecs from ``core/quant.py``: each shard
quantizes its local contribution, the PACKED codes + per-group scales are
all-gathered (that is the only cross-device traffic), and every shard
dequantizes and sums locally.

Error bound: each shard contributes at most scale/2 per element of rounding
error, so the sum over N shards is within N * max(scale)/2 of the exact psum
(``tests/test_substrate.py::test_compression_error_bound_simulated_shards``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quant import (QuantConfig, compute_qparams, dequantize_codes,
                              pack_codes, quantize_codes, unpack_codes,
                              vals_per_word)

__all__ = ["compressed_psum", "argmin_allgather", "elite_broadcast"]


def elite_broadcast(tree, owner, axis_name: str):
    """Broadcast ``owner``'s pytree to every shard of ``axis_name``
    (shard_map context only; ``owner`` may be traced, e.g. the index
    ``argmin_allgather`` returned).

    This is the island search's elite-STATE exchange: after the scalar
    argmin picks the winning island, the winner's transform + fake-quant
    stacks move across the mesh in one all-gather-and-take per leaf, and the
    losing shard splices them into its own state. Exact — pure data
    movement, no arithmetic on the payload."""
    def one(x):
        return jnp.take(jax.lax.all_gather(x, axis_name),
                        jnp.asarray(owner, jnp.int32), axis=0)
    return jax.tree.map(one, tree)


def argmin_allgather(x, axis_name: str):
    """(global min, owning shard index) of a per-shard scalar over
    ``axis_name`` (shard_map context only).

    One scalar all-gather — the entire cross-host cost of the island search's
    elite migration (``repro.search.islands``): each data-axis shard runs an
    independent island and only the winning loss/owner is exchanged.
    """
    xs = jax.lax.all_gather(jnp.asarray(x, jnp.float32), axis_name)
    i = jnp.argmin(xs)
    return xs[i], i.astype(jnp.int32)


def compressed_psum(x, axis_name: str, *, bits: int = 8, group: int = 32):
    """Group-quantized ``psum`` over ``axis_name`` (shard_map context only).

    x: any-shape float array (flattened internally; groups run along the
    flattened axis, padded to lcm(group, vals_per_word)). Returns the
    all-reduced array in ``x``'s shape/dtype, accurate to ~scale/2 per shard
    per element.
    """
    cfg = QuantConfig(bits=bits, group_size=group)
    vpw = vals_per_word(bits)
    flat = x.reshape(-1).astype(jnp.float32)
    unit = group * vpw // math.gcd(group, vpw)  # lcm: pack AND group aligned
    pad = (-flat.size) % unit
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])

    # local encode: (K,) -> packed uint32 (K/vpw,), scale/zero (K/group,)
    scale, zero = compute_qparams(flat, cfg)
    packed = pack_codes(quantize_codes(flat, scale, zero, cfg), bits)

    # the wire: packed codes + qparams, gathered across the axis
    g_packed = jax.lax.all_gather(packed, axis_name)   # (n_shards, K/vpw)
    g_scale = jax.lax.all_gather(scale, axis_name)
    g_zero = jax.lax.all_gather(zero, axis_name)

    # local decode + reduce
    def deq(p, s, z):
        codes = unpack_codes(p, bits, flat.size)
        return dequantize_codes(codes, s, z, cfg)

    total = jnp.sum(jax.vmap(deq)(g_packed, g_scale, g_zero), axis=0)
    return total[:x.size].reshape(x.shape).astype(x.dtype)
