"""Sharding rules: PartitionSpec trees over the ("data", "model") mesh.

One ``ShardingRules`` object holds the mesh geometry plus the model config and
answers every "where does this leaf live?" question:

  - ``param_specs``     — megatron-style tensor parallelism over "model":
                          attention QKV / MLP up+gate column-sharded, WO / MLP
                          down row-sharded, MoE experts sharded on the expert
                          dim, vocab-sharded embeddings. Head-aware: an arch
                          whose (kv-)head count does not divide the model axis
                          replicates those weights instead (internvl2's 14
                          heads on a model=16 axis).
  - ``opt_state_specs`` — param specs for m/v, plus ZeRO-1: the first free
                          (replicated) dim that divides the data axis is
                          sharded over "data".
  - ``cache_specs``     — decode KV caches: batch-sharded over "data" when the
                          batch divides it, otherwise sequence-sharded (the
                          long_500k batch-1 cell) or head-sharded
                          (``long_decode_shard="heads"``).
  - ``data_spec``       — token batches over "data", replicated fallback for
                          unshardable batch sizes.

Every proposed axis is divisibility-gated: a dim that does not divide its mesh
axis falls back to ``None`` (replication) rather than producing an invalid
partitioning. QTensor leaves get component-wise specs (packed / scale / zero
each re-gated against their own row counts — packed rows are K/vals_per_word,
scale rows K/group, and either may lose divisibility the logical K had).
"""
from __future__ import annotations

from jax.sharding import NamedSharding, PartitionSpec as P

import jax

from repro.core.quant import QTensor

__all__ = ["ShardingRules", "param_specs", "opt_state_specs", "cache_specs",
           "data_spec", "to_shardings"]


class ShardingRules:
    """Mesh geometry + model config -> sharding decisions.

    Works with a real ``jax.sharding.Mesh`` or anything exposing ``.shape``
    (axis name -> size mapping). Batch-parallel dims shard over every
    data-like axis present ("pod" and "data" on the multi-pod mesh).
    """

    def __init__(self, mesh, cfg, *, zero1: bool = False,
                 long_decode_shard: str = "seq"):
        if long_decode_shard not in ("seq", "heads"):
            raise ValueError(f"long_decode_shard must be 'seq' or 'heads', "
                             f"got {long_decode_shard!r}")
        shape = dict(mesh.shape)
        self.mesh = mesh
        self.cfg = cfg
        self.zero1 = zero1
        self.long_decode_shard = long_decode_shard
        self.model = int(shape.get("model", 1))
        self.has_model = "model" in shape and self.model > 1
        self.batch_axes = tuple(a for a in ("pod", "data") if a in shape)
        self.data = 1
        for a in self.batch_axes:
            self.data *= int(shape[a])
        self.has_data = self.data > 1

    @property
    def batch_entry(self):
        """The PartitionSpec entry for a batch-parallel dim."""
        if not self.batch_axes:
            return None
        return self.batch_axes[0] if len(self.batch_axes) == 1 else self.batch_axes

    # -- head gates: sharding a head-structured dim is only coherent when the
    #    head count itself divides the axis (else a head would straddle shards)
    @property
    def heads_ok(self) -> bool:
        return self.has_model and self.cfg.n_heads > 0 \
            and self.cfg.n_heads % self.model == 0

    @property
    def kv_heads_ok(self) -> bool:
        return self.has_model and self.cfg.n_kv_heads > 0 \
            and self.cfg.n_kv_heads % self.model == 0

    @property
    def ssm_heads_ok(self) -> bool:
        if not (self.has_model and self.cfg.ssm is not None):
            return False
        return self.cfg.ssm.n_heads(self.cfg.d_model) % self.model == 0


# ---------------------------------------------------------------------------
# Tree walking (dicts / tuples / QTensor nodes, paths preserved)
# ---------------------------------------------------------------------------

def _map_tree(fn, tree, path=()):
    if isinstance(tree, QTensor):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: _map_tree(fn, v, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return tuple(_map_tree(fn, v, path + (i,)) for i, v in enumerate(tree))
    if tree is None:
        return None
    return fn(path, tree)


# ---------------------------------------------------------------------------
# Per-leaf tensor-parallel proposals
# ---------------------------------------------------------------------------

_MLP_COL = ("up", "gate", "b_up", "b_gate")   # output (N) dim sharded
_ATTN_Q = ("wq", "bq")                         # q-head-structured outputs
_ATTN_KV = ("wk", "wv", "bk", "bv")            # kv-head-structured outputs


def _propose(rules: ShardingRules, path) -> int | None:
    """Negative trailing-dim index to shard over "model", or None."""
    if not rules.has_model:
        return None
    names = tuple(str(k) for k in path)
    leaf = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    if parent in ("attn", "xattn"):
        if leaf in _ATTN_Q and rules.heads_ok:
            return -1
        if leaf in _ATTN_KV and rules.kv_heads_ok:
            return -1
        if leaf == "wo" and rules.heads_ok:
            return -2
        return None
    if parent == "mlp":
        if leaf in _MLP_COL:
            return -1
        if leaf == "down":
            return -2
        return None
    if parent == "moe":
        if leaf in ("up", "down", "gate"):
            return -3  # expert dim of (..., E, D, F)
        return None  # router stays replicated (tiny, feeds top_k)
    if parent == "ssm":
        if leaf in ("w_z", "w_x") and rules.ssm_heads_ok:
            return -1
        if leaf == "out_proj" and rules.ssm_heads_ok:
            return -2
        return None
    if parent == "embed":
        return -2 if leaf == "tok" else None  # vocab-sharded embedding
    if leaf == "lm_head":
        return -1  # (D, V): vocab-sharded output projection
    return None


def _gated(rules: ShardingRules, ndim: int, shape, pos) -> P:
    """Full-rank spec with "model" at ``pos`` iff that dim divides the axis."""
    entries = [None] * ndim
    if pos is not None and -pos <= ndim and shape[pos] % rules.model == 0:
        entries[pos] = "model"
    return P(*entries)


def _leaf_spec(rules: ShardingRules, path, shape) -> P:
    return _gated(rules, len(shape), shape, _propose(rules, path))


def _qtensor_specs(rules: ShardingRules, path, qt: QTensor) -> QTensor:
    """Component specs for a packed QTensor leaf.

    The logical (K, N) weight decides the trailing axis; each component then
    re-gates on its OWN dim size at that position — packed rows (K/vpw) and
    scale rows (K/group) may not stay divisible even when K was.
    """
    pos = _propose(rules, path)
    if pos is not None and not (-pos <= len(qt.shape)
                                and qt.shape[pos] % rules.model == 0):
        pos = None  # logical weight itself unshardable -> replicate everywhere

    def comp(arr) -> P:
        return _gated(rules, len(arr.shape), arr.shape, pos)

    return QTensor(packed=comp(qt.packed), scale=comp(qt.scale),
                   zero=comp(qt.zero), bits=qt.bits, group_size=qt.group_size,
                   shape=qt.shape)


# ---------------------------------------------------------------------------
# Public spec builders
# ---------------------------------------------------------------------------

def param_specs(rules: ShardingRules, structs):
    """Spec tree mirroring a param (or packed-QTensor-param) struct tree."""
    def leaf(path, node):
        if isinstance(node, QTensor):
            return _qtensor_specs(rules, path, node)
        return _leaf_spec(rules, path, node.shape)
    return _map_tree(leaf, structs)


def _zero1_spec(rules: ShardingRules, spec: P, shape) -> P:
    """Shard the first free dim that divides the data axis over "data"."""
    if not rules.has_data:
        return spec
    entries = list(spec)
    for i, (ax, dim) in enumerate(zip(entries, shape)):
        if ax is None and dim % rules.data == 0:
            entries[i] = rules.batch_entry
            return P(*entries)
    return spec


def opt_state_specs(rules: ShardingRules, structs):
    """Specs for the AdamW state over PARAM structs: {"m", "v", "step"}.

    m/v mirror the param specs; with ``zero1=True`` each state leaf
    additionally shards one free axis over "data" (optimizer-state ZeRO-1 —
    params/grads stay data-replicated, only m/v split)."""
    def leaf(path, node):
        if isinstance(node, QTensor):
            return _qtensor_specs(rules, path, node)
        spec = _leaf_spec(rules, path, node.shape)
        if rules.zero1:
            spec = _zero1_spec(rules, spec, node.shape)
        return spec
    mv = _map_tree(leaf, structs)
    return {"m": mv, "v": mv, "step": P()}


def cache_specs(rules: ShardingRules, cfg, batch: int):
    """Decode-cache specs for ``init_cache(cfg, batch, max_len)`` trees.

    batch divides the data axis  -> batch-sharded (decode_32k: 128 over 16);
    otherwise                    -> sequence-sharded over "data" (long_500k:
                                    batch 1), or head-sharded when
                                    ``long_decode_shard="heads"``.
    KV head dims shard over "model" only when the kv-head count divides it.
    """
    batch_ok = rules.has_data and batch % rules.data == 0
    b_ax = rules.batch_entry if batch_ok else None
    seq_ax = None
    if not batch_ok and rules.has_data and rules.long_decode_shard == "seq":
        seq_ax = rules.batch_entry
    h_ax = "model" if rules.kv_heads_ok else None

    def dense_cache():
        kv = P(None, b_ax, seq_ax, h_ax, None)
        c = {"k": kv, "v": kv}
        if cfg.kv_cache_dtype == "int8":
            sc = P(None, b_ax, seq_ax, h_ax)
            c["k_scale"] = sc
            c["v_scale"] = sc
        return c

    def ssm_cache():
        sh_ax = "model" if rules.ssm_heads_ok else None
        return {
            "state": P(None, b_ax, sh_ax, None, None),   # (L, B, H, hd, N)
            "conv": {"x": P(None, b_ax, None, sh_ax),    # (L, B, W, di)
                     "B": P(None, b_ax, None, None),
                     "C": P(None, b_ax, None, None)},
        }

    if cfg.block_pattern in ("dense", "moe"):
        return dense_cache()
    if cfg.block_pattern == "ssm":
        return ssm_cache()
    if cfg.block_pattern == "hybrid":
        return {"ssm": ssm_cache(), "attn": dense_cache()}
    raise ValueError(cfg.block_pattern)


def data_spec(rules: ShardingRules, batch: int) -> P:
    """(B, S) token batches: batch over "data" when divisible, else replicate
    (an unshardable batch is a correctness fallback, not an error)."""
    ok = rules.has_data and batch % rules.data == 0
    return P(rules.batch_entry if ok else None, None)


def to_shardings(mesh, tree):
    """Map every PartitionSpec leaf to a NamedSharding on ``mesh`` (QTensor
    spec nodes flatten to their component specs)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda x: isinstance(x, P))
