"""Sequence-sharded decode attention via partial-softmax (log-sum-exp) merge.

The long_500k cell shards the KV cache along SEQUENCE (batch 1 cannot shard
over "data"). Each shard computes attention stats over its local KV slice:

    acc_i = sum_s exp(s - m_i) * v_s      (unnormalized output)
    m_i   = max_s(scores)                 (running max)
    l_i   = sum_s exp(s - m_i)            (normalizer mass)

and the merge recovers EXACT dense softmax attention:

    m*  = max_i m_i
    out = sum_i exp(m_i - m*) acc_i / sum_i exp(m_i - m*) l_i

— the same identity flash attention uses across KV blocks, applied across
devices. ``sharded_decode_attention`` does the merge with pmax/psum inside
shard_map; ``merge_partials`` is the collective-free oracle used in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["partial_decode_attention", "merge_partials",
           "sharded_decode_attention", "sharded_paged_decode_attention"]

_MASKED = -1e30  # matches kernels/ref.py masking (finite: no NaN via inf-inf)


def partial_decode_attention(q, k, v, *, kv_len=None, start=0):
    """One-token attention stats over a local KV shard.

    q: (B, H, Dh); k/v: (B, S_shard, H, Dh). ``start`` is this shard's global
    sequence offset; positions >= ``kv_len`` are masked out. Returns
    (acc (B, H, Dh), m (B, H), l (B, H)) in float32.

    A fully-masked shard degrades safely: m == _MASKED makes its merge weight
    exp(m - m*) underflow to exactly 0.
    """
    dh = q.shape[-1]
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if kv_len is not None:
        pos = start + jnp.arange(k.shape[1])
        s = jnp.where(pos[None, None, :] < kv_len, s, _MASKED)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return acc, m, l


def merge_partials(acc, m, l):
    """Merge stacked shard stats -> dense softmax attention output.

    acc: (N, B, H, Dh); m, l: (N, B, H) — leading axis indexes shards.
    """
    m_star = jnp.max(m, axis=0)
    alpha = jnp.exp(m - m_star[None])           # (N, B, H)
    num = jnp.sum(alpha[..., None] * acc, axis=0)
    den = jnp.sum(alpha * l, axis=0)
    return num / den[..., None]


def sharded_paged_decode_attention(q, k_pages, v_pages, block_tables,
                                   seq_lens, axis_name, k_scale=None,
                                   v_scale=None, *, use_pallas=True):
    """Decode attention over a sequence-sharded PAGED cache (shard_map body).

    Each device owns a page pool holding its slice of every sequence plus
    the matching per-shard block tables (B, P_local) and LOCAL lengths (B,)
    — the paged analogue of ``sharded_decode_attention``. The Pallas kernel
    (``kernels.paged_decode`` with ``normalize=False``) emits the exact
    (acc, m, l) log-sum-exp partials this merge needs, so paging composes
    with sequence sharding at the cost of the same two O(B*H*Dh)
    collectives. Softmax weights depend only on scores, not positions, so
    local masking per shard merges exactly.
    """
    from repro.kernels import paged_decode  # deferred: dist stays importable
    acc, m, l = paged_decode(q, k_pages, v_pages, block_tables, seq_lens,
                             k_scale, v_scale, normalize=False,
                             use_pallas=use_pallas)
    m_star = jax.lax.pmax(m, axis_name)
    alpha = jnp.exp(m - m_star)
    num, den = jax.lax.psum((alpha[..., None] * acc, alpha * l), axis_name)
    return num / den[..., None]


def sharded_decode_attention(q, k, v, axis_name, *, shard_start=0, kv_len=None):
    """Decode attention over a sequence-sharded KV cache (shard_map context).

    q: (B, H, Dh) replicated; k/v: (B, S_local, H, Dh) — this device's
    sequence slice; ``shard_start`` is its global offset (typically
    ``jax.lax.axis_index(axis_name) * S_local``). Two collectives total
    (pmax + fused psum), both O(B*H*Dh), independent of sequence length.
    """
    acc, m, l = partial_decode_attention(q, k, v, kv_len=kv_len,
                                         start=shard_start)
    m_star = jax.lax.pmax(m, axis_name)
    alpha = jnp.exp(m - m_star)
    num, den = jax.lax.psum((alpha[..., None] * acc, alpha * l), axis_name)
    return num / den[..., None]
