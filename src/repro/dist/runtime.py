"""Multi-host runtime: ``jax.distributed`` init, psum barrier, introspection.

The rest of ``repro.dist`` is written against an already-alive mesh; this
module is the piece that brings the mesh up. Three responsibilities:

  - ``initialize()``   — env/flag driven ``jax.distributed`` bring-up
                         (coordinator address, process id/count). On CPU the
                         gloo TCP collectives backend is selected first, since
                         the default CPU client cannot run cross-process
                         computations at all. A single-process call (no
                         coordinator configured anywhere) is a NO-OP, so every
                         existing single-host entry point keeps working
                         untouched.
  - ``barrier()``      — a real synchronization point built on a tiny psum
                         over a host axis: every device contributes 1, every
                         process checks the sum equals the global device
                         count. No gRPC side channel, no timeout knob — if a
                         host is gone the collective itself fails, which is
                         exactly the signal the fault layer wants.
  - introspection      — ``process_index`` / ``process_count`` /
                         ``device_summary()`` plus the ``global_put`` /
                         ``replicated`` helpers that place host-local numpy
                         values onto a (possibly multi-process) mesh without
                         ever touching non-addressable shards
                         (``jax.make_array_from_callback`` materializes only
                         the local ones).

Env vars (flags win over env, env wins over nothing):
    REPRO_COORDINATOR   — "host:port" of process 0 (also accepts
                          JAX_COORDINATOR_ADDRESS)
    REPRO_NUM_PROCESSES — world size
    REPRO_PROCESS_ID    — this process's rank
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np

from repro.dist.compat import shard_map

__all__ = ["initialize", "is_distributed", "process_index", "process_count",
           "local_device_count", "global_device_count", "device_summary",
           "barrier", "global_put", "replicated"]

_AXIS = "hosts"
_initialized = False


def _env(name: str, alt: Optional[str] = None) -> Optional[str]:
    v = os.environ.get(name)
    if v is None and alt is not None:
        v = os.environ.get(alt)
    return v


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None,
               local_device_ids=None) -> bool:
    """Bring up ``jax.distributed`` (idempotent). Returns True iff a
    multi-process runtime is (now) alive.

    Resolution order per field: explicit argument, then env var
    (``REPRO_COORDINATOR`` / ``JAX_COORDINATOR_ADDRESS``,
    ``REPRO_NUM_PROCESSES``, ``REPRO_PROCESS_ID``). When no coordinator is
    configured anywhere — the plain single-host invocation — this is a no-op
    and every query below answers from the local backend (process 0 of 1).

    MUST run before the first jax computation: on CPU the gloo collectives
    client has to be selected before the backend exists (the default CPU
    client refuses cross-process computations outright).
    """
    global _initialized
    coordinator = coordinator or _env("REPRO_COORDINATOR",
                                      "JAX_COORDINATOR_ADDRESS")
    if num_processes is None and _env("REPRO_NUM_PROCESSES"):
        num_processes = int(_env("REPRO_NUM_PROCESSES"))
    if process_id is None and _env("REPRO_PROCESS_ID"):
        process_id = int(_env("REPRO_PROCESS_ID"))
    if _initialized:
        return jax.process_count() > 1
    if coordinator is None:
        if process_id not in (None, 0):
            raise ValueError(
                f"process_id={process_id} configured but no coordinator "
                f"address — set REPRO_COORDINATOR (a silently single-process "
                f"rank would split-brain the fleet)")
        return False  # single-process fallback: nothing to bring up
    if num_processes is None or num_processes < 1:
        # a configured coordinator with no world size must NOT degrade to
        # single-process mode: every rank would believe it is 0-of-1 and
        # fight over the same checkpoint files
        raise ValueError(
            f"coordinator {coordinator!r} configured but num_processes is "
            f"{num_processes!r} — set REPRO_NUM_PROCESSES")
    if num_processes == 1:
        return False  # explicit world of one: valid single-process run

    # CPU backend: the default client cannot run multi-process computations;
    # gloo (TCP) can. Must be set before backend init; older jax spells the
    # knob differently or lacks it, in which case distributed CPU is simply
    # unavailable and initialize() below will surface the real error.
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):
        pass

    kwargs = {}
    if local_device_ids is not None:
        kwargs["local_device_ids"] = local_device_ids
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id, **kwargs)
    _initialized = True
    return True


def is_distributed() -> bool:
    return jax.process_count() > 1


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def local_device_count() -> int:
    return jax.local_device_count()


def global_device_count() -> int:
    return jax.device_count()


def device_summary() -> dict:
    """Process-local view of the global topology (one dict per host; the CI
    lane prints it from every process as the bring-up receipt)."""
    local = jax.local_devices()
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": [d.id for d in local],
        "local_device_count": len(local),
        "global_device_count": jax.device_count(),
        "platform": local[0].platform if local else "none",
    }


def global_put(x, sharding):
    """Place a host-local numpy/jax value onto ``sharding`` (which may span
    processes). Every process must pass the same logical value; only the
    locally-addressable shards are materialized."""
    x = np.asarray(x)
    return jax.make_array_from_callback(x.shape, sharding,
                                        lambda idx: np.ascontiguousarray(x[idx]))


def replicated(x, mesh):
    """``global_put`` with a fully-replicated spec on ``mesh``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(
        lambda v: global_put(v, NamedSharding(mesh, P())), x)


_barrier_fns: dict = {}


def _barrier_fn():
    """Compiled psum-of-ones over every global device (cached per topology)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    devs = jax.devices()
    key = tuple(d.id for d in devs)
    if key not in _barrier_fns:
        mesh = Mesh(np.array(devs), (_AXIS,))
        sharding = NamedSharding(mesh, P(_AXIS))

        f = jax.jit(shard_map(
            lambda v: jax.lax.psum(v.sum(), _AXIS),
            mesh=mesh, in_specs=(jax.sharding.PartitionSpec(_AXIS),),
            out_specs=jax.sharding.PartitionSpec(), check_vma=False))

        def run():
            ones = jax.make_array_from_callback(
                (len(devs),), sharding, lambda idx: np.ones((1,), np.float32))
            return int(np.asarray(f(ones)))

        _barrier_fns[key] = run
    return _barrier_fns[key]


def barrier(tag: str = "") -> None:
    """Block until every process reaches this point.

    Implemented as a tiny psum over the host axis: each of the N global
    devices contributes 1 and every process verifies the all-reduced total is
    N — a wrong total means a peer ran a DIFFERENT collective (program
    divergence), which is worth failing loudly on rather than deadlocking
    later. Single-process runs execute the same psum on the local mesh (cheap,
    and it keeps the code path identical instead of special-cased).
    """
    from repro import obs
    with obs.trace_span("dist.barrier", tag=tag,
                        hist=obs.histogram("dist_barrier_seconds",
                                           "Barrier wait latency")):
        total = _barrier_fn()()
    n = jax.device_count()
    if total != n:
        raise RuntimeError(
            f"barrier({tag!r}) psum mismatch: got {total}, want {n} — "
            f"processes are running divergent programs")
