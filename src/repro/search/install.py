"""O(unit)-memory candidate install: dynamic-slice tree surgery.

A proposal touches ONE unit of the fake-quant stack, yet the v1 population
step materialized K *full* stacks (``_tree_update`` per candidate, then a
``vmap`` over the K-stacked trees) — memory = K × stack, the ROADMAP item-2
blocker. The v2 path keeps ONE stack plus K per-unit candidate buffers:

- :func:`tree_install_unit` writes one unit into the stacked tree via
  ``jax.lax.dynamic_update_slice`` (the generalized ``_tree_update``; for a
  concrete integer index the two lower identically, and the property tests
  pin install-mode equivalence bit-for-bit);
- :func:`eval_candidates_unit` folds a ``lax.map`` over the K unit buffers,
  installing each into the (XLA-donated) stack one at a time — peak live
  memory is stack + K × unit instead of (K+1) × stack;
- :func:`eval_candidates_stack` is the v1 semantics behind the same
  signature (``install="stack"``), kept for A/B benchmarking — the CI
  bench-smoke lane asserts unit-install peak live bytes < stack-install
  peak at K=8.

Both entry points take the K×unit candidate batch (a REAL stage output in
the engine's staged pipeline, so ``jax.live_arrays()`` sees exactly the
memory model being claimed) and return ``(primary, aux)`` vectors of shape
(K,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["tree_install_unit", "stack_unit_batch", "eval_candidates_unit",
           "eval_candidates_stack", "tree_bytes"]


def tree_install_unit(tree, u, unit):
    """Install ``unit`` (per-unit leaves) at index ``u`` (traced ok) along
    the leading axis of every leaf of the stacked ``tree``.

    Explicit ``dynamic_update_slice`` rather than ``x.at[u].set(n)`` so the
    O(unit) write is the lowered program by construction, not an indexing
    idiom the compiler may or may not canonicalize the same way.
    """
    def one(x, n):
        starts = (u,) + (jnp.int32(0),) * (x.ndim - 1)
        return jax.lax.dynamic_update_slice(
            x, n[None].astype(x.dtype), starts)

    return jax.tree.map(one, tree, unit)


def stack_unit_batch(units):
    """[unit pytree] * K -> one pytree with a leading K axis (the candidate
    buffer: K × unit, NOT K × stack)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *units)


def eval_candidates_unit(unit_batch, fq_stack, u, eval_fn):
    """Evaluate K candidates with O(unit) extra memory.

    ``lax.map`` runs the body sequentially, so only ONE installed stack is
    live at a time; the loop-carried state is nothing but the (K,) loss
    rows. ``eval_fn(fq) -> (primary, aux)`` is the full objective forward.
    """
    def body(unit_fq):
        return eval_fn(tree_install_unit(fq_stack, u, unit_fq))

    return jax.lax.map(body, unit_batch)


def eval_candidates_stack(unit_batch, fq_stack, u, eval_fn):
    """v1 semantics: materialize all K installed stacks and ``vmap`` the
    objective across them (memory = K × stack; fastest when it fits)."""
    fq_batch = jax.vmap(
        lambda unit_fq: tree_install_unit(fq_stack, u, unit_fq))(unit_batch)
    return jax.vmap(eval_fn)(fq_batch)


def tree_bytes(tree) -> int:
    """Total payload bytes of a pytree of arrays (memory-model reporting)."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))
