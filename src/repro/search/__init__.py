"""Parallel discrete-search framework (population × islands × objectives).

The paper's Algorithm 1 evaluates ONE proposal per step on one chain; this
package scales it along orthogonal axes while keeping the single-chain
greedy hill-climb as an exact special case:

- ``api.py``        — ``repro.search.run``, the one front door (adapter
  dispatch, hybrid two-phase composites, objective resolution);
- ``population.py`` — K candidate transforms per step for the sampled unit;
- ``install.py``    — O(unit)-memory candidate install: ONE fake-quant
  stack + K per-unit buffers via ``dynamic_update_slice`` tree surgery
  (``install="unit"``, the default) or the v1 K-full-stacks ``vmap`` lane
  (``install="stack"``);
- ``anneal.py``     — temperature schedules + the Metropolis acceptance rule
  (T=0 reduces bit-for-bit to the legacy accept-iff-better);
- ``islands.py``    — independent populations with counter-based per-island
  key streams and elite migration on a fixed cadence; with
  ``shard_calib=True`` each island climbs on its own calibration slice;
- ``tabu.py``       — tried-point dedup memory replaying cached scalars for
  proposals already evaluated at the current chain state;
- ``engine.py``     — the loop that composes all of the above.

Objectives are pluggable (``repro.core.objective``): ``"ce"`` (the paper's
Eqn. 23 default), ``"kl"``, ``"swd_actmatch"``, ``"saliency_ce"``, or any
registered/passed ``Objective`` instance.
"""
from repro.search.anneal import accept, temperature_schedule
from repro.search.api import run
from repro.search.engine import run_population_search
from repro.search.install import tree_install_unit
from repro.search.islands import IslandState, migrate
from repro.search.population import candidate_keys
from repro.search.tabu import TabuMemory

__all__ = ["run", "run_population_search", "temperature_schedule", "accept",
           "IslandState", "migrate", "candidate_keys", "tree_install_unit",
           "TabuMemory"]
