"""Parallel discrete-search engine (population × islands).

The paper's Algorithm 1 evaluates ONE proposal per step on one chain; this
package scales it along two orthogonal axes while keeping the single-chain
greedy hill-climb as an exact special case:

- ``population.py`` — K candidate transforms per step for the sampled unit,
  all K evaluated in one vmap-batched transform→fake-quant→forward→loss
  program (the calibration forward is amortized across candidates);
- ``anneal.py``    — temperature schedules + the Metropolis acceptance rule
  (T=0 reduces bit-for-bit to the legacy accept-iff-better);
- ``islands.py``   — independent populations with counter-based per-island
  key streams and elite migration on a fixed cadence (in-process loop here;
  ``elite_over_mesh`` is the ``repro.dist`` building block for the
  designed-for mesh-mapped execution, not yet wired);
- ``engine.py``    — the loop that composes the three.

``repro.core.search.run_search`` is a thin adapter-compatible front-end over
``engine.run_population_search``.
"""
from repro.search.anneal import accept, temperature_schedule
from repro.search.engine import run_population_search
from repro.search.islands import IslandState, migrate
from repro.search.population import candidate_keys

__all__ = ["run_population_search", "temperature_schedule", "accept",
           "IslandState", "migrate", "candidate_keys"]
