"""Population × island annealed discrete search (the Algorithm 1 engine).

One engine, three nested degrees of freedom, each defaulting to the paper's
single-chain hill climb:

- population K: K candidate transforms for the step's unit, evaluated in ONE
  vmap-batched transform→fake-quant→forward→loss program (the calibration
  forward is amortized K ways); the per-step move is the argmin candidate.
- temperature T: Metropolis acceptance of the chosen candidate under an
  annealing schedule; T=0 is the strict accept-iff-better rule.
- islands: independent chains with per-island counter-based key streams and
  elite migration on a fixed cadence (``repro.search.islands``).

Bit-for-bit contract: at ``population=1, islands=1, temperature=0`` the
engine's proposal keys, unit picks, jitted programs and accept decisions are
EXACTLY the legacy ``core/search.py`` loop's, so the accepted-move trajectory
reproduces the paper configuration unchanged (pinned by
``tests/test_search_engine.py``).

Execution modes:

- sequential (default): islands run one after another in-process — the
  reference semantics, and the only mode a 1-device host can run.
- ``mapped=True``: one island per shard of a 1-D ("data",) mesh over ALL
  global devices, stepped inside ``shard_map``. Every process replays every
  island's HOST streams (unit picks, accept draws — cheap scalars), so the
  accept logic stays on the host exactly as in sequential mode; only the
  expensive proposal evaluation runs on-device, one island per shard, and
  the per-migration traffic is one scalar ``argmin_allgather`` plus the
  winner's state via ``elite_broadcast``. The mapped trajectory is pinned
  BIT-FOR-BIT equal to the sequential island loop on a 1-host multi-device
  mesh (``tests/test_search_mapped.py``), and the same code runs unchanged
  under a real multi-process ``jax.distributed`` mesh (the CI ``distributed``
  lane drives 2 processes through ``repro.launch.dist_smoke``).
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import invariance as inv
from repro.core import objective as obj
from repro.models.model import forward
from repro.search import anneal
from repro.search.islands import (IslandState, make_island_streams, migrate,
                                  migrate_on_mesh)
from repro.search.population import candidate_keys, stack_trees, take_tree

__all__ = ["run_population_search"]


def _search_metrics():
    """Instrument handles on the process registry (get-or-create, so a
    registry ``reset()`` between runs keeps these valid)."""
    reg = obs.get_registry()
    return {
        "proposals": reg.counter(
            "search_proposals_total", "Candidate transforms proposed"),
        "accepts": reg.counter(
            "search_accepts_total", "Moves accepted by the Metropolis rule"),
        "uphill": reg.counter(
            "search_uphill_accepts_total",
            "Accepted strictly-worse (uphill) moves"),
        "migrations": reg.counter(
            "search_migrations_total", "Elite island migrations applied"),
        "best": reg.gauge(
            "search_objective_best", "Best combined objective seen so far"),
        "temp": reg.gauge(
            "search_temperature", "Annealing temperature at the last step"),
        "step": reg.histogram(
            "search_step_seconds", "Wall time of one full search step"),
        "eval": reg.histogram(
            "search_eval_seconds",
            "Proposal evaluation latency (dispatch + loss sync)"),
    }


def _tree_slice(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _tree_update(tree, i, new):
    return jax.tree.map(lambda x, n: x.at[i].set(n), tree, new)


def run_population_search(
    params_fp: dict,
    params_base: dict,
    cfg,
    qcfg,
    calib_tokens: jnp.ndarray,
    scfg,
    adapter,
    forward_kwargs: Optional[dict] = None,
):
    """Run the engine; returns a ``core.search.SearchResult``.

    ``params_fp`` / ``params_base`` follow the ``core.search.run_search``
    contract (FP reference model; base-method continuous-domain FFN weights
    with everything else already fake-quantized).
    """
    from repro.core.search import SearchResult  # front-end owns the dataclass

    fwd_kw = forward_kwargs or {}
    n_match = min(scfg.n_match_layers, cfg.n_layers)
    K = max(int(getattr(scfg, "population", 1)), 1)
    n_islands = max(int(getattr(scfg, "islands", 1)), 1)
    migrate_every = int(getattr(scfg, "migrate_every", 0))
    mapped = bool(getattr(scfg, "mapped", False))
    fused = bool(getattr(scfg, "fused_kernel", False))
    if fused and not hasattr(adapter, "transform_quant_unit"):
        warnings.warn(
            f"fused_kernel=True but adapter {type(adapter).__name__} has no "
            f"transform_quant_unit; falling back to the unfused "
            f"transform->quantize path", stacklevel=2)
        fused = False

    base = adapter.base_stack(params_base)
    proposer = getattr(adapter, "propose", None) or (
        lambda key, t, pcfg: inv.propose(key, t, pcfg))

    # identity transforms + initial fake-quant of every unit (per-unit slices
    # hit quant_unit so the ndim>=2 "skip biases" check stays correct)
    t0 = inv.identity_transform(adapter.f_dim)
    transforms0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (adapter.n_units,) + x.shape).copy(), t0)
    fq0 = jax.vmap(lambda b: adapter.quant_unit(b, qcfg))(base)

    # reference forward (FP model)
    logits_fp, hidden_fp = forward(params_fp, cfg, calib_tokens,
                                   collect_hidden=True, **fwd_kw)
    hidden_fp = jax.lax.stop_gradient(hidden_fp[:n_match]) if n_match else None
    logits_fp = jax.lax.stop_gradient(logits_fp)

    # everything the proposal evaluation reads besides per-island state; the
    # mapped mode ships this tree to the global mesh replicated, the
    # sequential mode closes over it exactly as the legacy loop did
    env = {"base": base, "params_base": params_base, "calib": calib_tokens,
           "logits_fp": logits_fp, "hidden_fp": hidden_fp}

    def eval_stack_fn(fq, env):
        params_q = adapter.install(env["params_base"], fq)
        logits, hidden = forward(params_q, cfg, env["calib"],
                                 collect_hidden=True, **fwd_kw)
        if scfg.objective == "kl":
            ce = obj.calib_kl(logits, env["logits_fp"], cfg.vocab_size)
        else:
            ce = obj.calib_ce(logits, env["calib"], cfg.vocab_size)
        mse = (obj.activation_mse(hidden, env["hidden_fp"], n_match)
               if n_match else jnp.float32(0.0))
        return ce, mse

    eval_stack = jax.jit(lambda fq: eval_stack_fn(fq, env))

    ce0, mse0 = map(float, eval_stack(fq0))
    alpha = obj.resolve_alpha(ce0, mse0, scfg.ce_weight) if n_match else 0.0
    loss0 = ce0 + alpha * float(mse0)

    def quant_candidate(t_new, u, env):
        if fused:
            return adapter.transform_quant_unit(env["base"], t_new, u, qcfg)
        unit = adapter.transform_unit(env["base"], t_new, u)
        return adapter.quant_unit(unit, qcfg)

    def step_body_single(key, transforms, fq_stack, u, env):
        # EXACTLY the legacy step: one proposal, unbatched evaluation — keeps
        # the K=1 trajectory bit-identical to the original hill climb.
        k_prop, _ = jax.random.split(key)
        t_u = _tree_slice(transforms, u)
        t_new = proposer(k_prop, inv.FFNTransform(*t_u), scfg.proposal)
        unit = adapter.transform_unit(env["base"], t_new, u)
        unit_fq = adapter.quant_unit(unit, qcfg)
        fq_new = _tree_update(fq_stack, u, unit_fq)
        ce, mse = eval_stack_fn(fq_new, env)
        loss = ce + alpha * mse
        return loss, ce, mse, fq_new, t_new

    def step_body_population(key, transforms, fq_stack, u, env):
        keys = candidate_keys(key, K)
        t_u = inv.FFNTransform(*_tree_slice(transforms, u))
        cands = [proposer(keys[i], t_u, scfg.proposal) for i in range(K)]
        fq_news = [_tree_update(fq_stack, u, quant_candidate(t, u, env))
                   for t in cands]
        fq_batch = stack_trees(fq_news)          # (K, n_units, ...)
        ce, mse = jax.vmap(lambda fq: eval_stack_fn(fq, env))(fq_batch)
        loss = ce + alpha * mse                  # ONE batched forward above
        i = jnp.argmin(loss)
        return (loss[i], ce[i], mse[i], take_tree(fq_batch, i),
                take_tree(stack_trees(cands), i))

    step_body = (step_body_single if (K == 1 and not fused)
                 else step_body_population)
    schedule = anneal.temperature_schedule(
        getattr(scfg, "anneal", "geometric"),
        float(getattr(scfg, "temperature", 0.0)), scfg.steps)

    stats = {"migrations": 0, "uphill_accepts": 0,
             "proposals": scfg.steps * K * n_islands, "fused": fused,
             "mapped": mapped}
    metrics = _search_metrics()
    metrics["best"].set(loss0)

    if mapped:
        return _run_mapped_islands(
            SearchResult, adapter, scfg, env, step_body, schedule, stats,
            transforms0, fq0, loss0, ce0, mse0, n_islands, migrate_every,
            metrics)

    step_fn = jax.jit(
        lambda key, transforms, fq_stack, u:
            step_body(key, transforms, fq_stack, u, env))

    islands = []
    for i in range(n_islands):
        rng, key = make_island_streams(scfg.seed, i)
        islands.append(IslandState(
            index=i, rng=rng, key=key, transforms=transforms0, fq_stack=fq0,
            current_loss=loss0, best_loss=loss0, best_transforms=transforms0,
            best_fq=fq0, history=[(0, loss0, ce0, float(mse0), True)]))

    with obs.trace_span("search.run", mode="sequential",
                        islands=n_islands, population=K) as run_span:
        for step in range(1, scfg.steps + 1):
            T = schedule(step)
            with obs.trace_span("search.step", step=step,
                                hist=metrics["step"]):
                for isl in islands:
                    isl.key, sub = jax.random.split(isl.key)
                    u = jnp.int32(isl.rng.integers(adapter.n_units))
                    with obs.trace_span("search.eval",
                                        hist=metrics["eval"]):
                        loss, ce, mse, fq_new, t_new = step_fn(
                            sub, isl.transforms, isl.fq_stack, u)
                        loss = float(loss)   # the device sync
                    metrics["proposals"].inc(K)
                    delta = loss - isl.current_loss
                    uniform = isl.rng.random() if T > 0.0 else None
                    accepted = anneal.accept(delta, T, uniform)
                    if accepted:
                        # strictly-worse moves only (delta == 0 is lateral,
                        # not uphill), counted as a Python int — not an
                        # accumulated numpy bool
                        if delta > 0.0:
                            stats["uphill_accepts"] += 1
                            metrics["uphill"].inc()
                        metrics["accepts"].inc()
                        isl.current_loss = loss
                        isl.fq_stack = fq_new
                        isl.transforms = _tree_update(isl.transforms, u,
                                                      t_new)
                        isl.n_accept += 1
                        if loss < isl.best_loss:
                            isl.best_loss = loss
                            isl.best_transforms = isl.transforms
                            isl.best_fq = isl.fq_stack
                    isl.history.append(
                        (step, loss, float(ce), float(mse), accepted))
                if migrate_every and n_islands > 1 \
                        and step % migrate_every == 0:
                    n_migrated = migrate(islands)
                    stats["migrations"] += n_migrated
                    metrics["migrations"].inc(n_migrated)
            metrics["best"].set(min(s.best_loss for s in islands))
            metrics["temp"].set(T)
            if scfg.log_every and step % scfg.log_every == 0:
                best = min(s.best_loss for s in islands)
                rate = sum(s.n_accept for s in islands) / (step * n_islands)
                obs.emit("search", step=step, best=f"{best:.5f}",
                         accept=f"{rate:.2%}", T=f"{T:.4g}",
                         elapsed_s=f"{run_span.elapsed():.1f}")

    elite = min(islands, key=lambda s: s.best_loss)
    # monotonic clock (run_span.dur): wall time steps backwards under NTP
    stats["proposals_per_sec"] = stats["proposals"] / max(run_span.dur, 1e-9)
    return SearchResult(
        params_q=adapter.install(params_base, elite.best_fq),
        transforms=elite.best_transforms,
        history=elite.history,
        accept_rate=elite.n_accept / max(scfg.steps, 1),
        final_loss=elite.best_loss,
        initial_loss=loss0,
        island_histories=[s.history for s in islands],
        stats=stats,
    )


# ---------------------------------------------------------------------------
# mapped mode: one island per shard of the ("data",) mesh
# ---------------------------------------------------------------------------

def _run_mapped_islands(SearchResult, adapter, scfg, env, step_body, schedule,
                        stats, transforms0, fq0, loss0, ce0, mse0,
                        n_islands, migrate_every, metrics):
    """The mapped island loop: one island per shard of the ("data",) mesh.

    Split of responsibilities, chosen so "bit-for-bit equal to sequential"
    is a property of the construction rather than a hope about the compiler:

    - the per-island STEP (propose → transform → fake-quant → forward → loss)
      runs the SAME ``jax.jit(step_body)`` program the sequential engine
      runs, with the island's state committed to its shard's device — XLA
      generates identical code for identical programs, so the per-step
      scalars come out bit-identical island by island. (Running the step
      *inside* shard_map instead was measurably NOT bit-stable: the
      surrounding slice/gather graph perturbs how XLA fuses the loss
      reductions, and ``optimization_barrier`` does not fence it off.)
    - everything CROSS-island runs inside ``shard_map`` over the island axis
      and is pure data movement, which is exact: the per-step scalar
      exchange (an all-gather of each shard's (loss, ce, mse) row), and the
      per-migration elite exchange — ``argmin_allgather`` for the scalar
      race, ``elite_broadcast`` for the winner's state, a masked select for
      the splice (``islands.migrate_on_mesh``).
    - control stays on the host: every process replays every island's host
      streams (unit picks, accept uniforms — cheap scalars), so the accept
      logic and histories are computed identically everywhere, and each
      process steps only the islands whose shard devices it owns.

    Under a multi-process ``jax.distributed`` runtime the same loop runs
    unchanged: hosts step their local islands independently and meet only at
    the scalar exchange and migrations (the CI ``distributed`` lane pins 2
    processes against the single-process sequential result).
    """
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.dist import runtime
    from repro.dist.compat import shard_map
    from repro.dist.collectives import elite_broadcast
    from repro.search.islands import gather_island_states, scatter_island_states

    devs = jax.devices()
    if n_islands != len(devs):
        raise ValueError(
            f"mapped=True runs one island per device shard: islands="
            f"{n_islands} but the mesh has {len(devs)} global devices "
            f"(match --islands to the device count, or force devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    mesh = Mesh(np.array(devs), ("data",))
    shd = NamedSharding(mesh, P("data"))
    pid = jax.process_index()
    local = {i: d for i, d in enumerate(devs) if d.process_index == pid}
    multiproc = jax.process_count() > 1

    step_fn = jax.jit(
        lambda key, transforms, fq_stack, u:
            step_body(key, transforms, fq_stack, u, env))

    # per-LOCAL-island state, committed to the island's shard device (the
    # cross-host stacked layout only materializes for migrations/fetch)
    t_loc = {i: jax.device_put(transforms0, d) for i, d in local.items()}
    fq_loc = {i: jax.device_put(fq0, d) for i, d in local.items()}
    bt_loc = dict(t_loc)
    bfq_loc = dict(fq_loc)

    exchange = jax.jit(shard_map(
        lambda rows: jax.lax.all_gather(rows[0], "data"),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(), check_vma=False))

    migrate_mapped = jax.jit(shard_map(
        lambda bl, cl, t, fq, bt, bfq: migrate_on_mesh(
            bl, cl, t, fq, bt, bfq, "data"),
        mesh=mesh,
        in_specs=(P("data"),) * 6,
        out_specs=((P("data"),) * 4) + (P(),),
        check_vma=False))

    def put_shd(x):
        return runtime.global_put(x, shd)

    streams = [make_island_streams(scfg.seed, i) for i in range(n_islands)]
    rngs = [s[0] for s in streams]
    keys = [s[1] for s in streams]
    cur = [loss0] * n_islands
    best = [loss0] * n_islands
    n_accept = [0] * n_islands
    histories = [[(0, loss0, ce0, float(mse0), True)]
                 for _ in range(n_islands)]

    pid0 = jax.process_index() == 0
    run_span = obs.trace_span("search.run", mode="mapped",
                              islands=n_islands).__enter__()
    for step in range(1, scfg.steps + 1):
        T = schedule(step)
        step_span = obs.trace_span("search.step", step=step,
                                   hist=metrics["step"]).__enter__()
        subs = [None] * n_islands
        us = [None] * n_islands
        for i in range(n_islands):
            # replay EVERY island's streams so hosts stay in lock-step; only
            # the local islands are evaluated
            keys[i], sub = jax.random.split(keys[i])
            subs[i] = sub
            us[i] = int(rngs[i].integers(adapter.n_units))
        with obs.trace_span("search.eval", hist=metrics["eval"]):
            outs = {}
            u_dev = {}
            for i, d in local.items():   # dispatch all, then fetch (async)
                u_dev[i] = jax.device_put(jnp.int32(us[i]), d)
                outs[i] = step_fn(jax.device_put(subs[i], d), t_loc[i],
                                  fq_loc[i], u_dev[i])
            scal = np.zeros((n_islands, 3), np.float32)
            for i, out in outs.items():
                scal[i] = [float(out[0]), float(out[1]), float(out[2])]
        # each host counts only its LOCAL islands, so the dist_snapshot sum
        # over hosts reconciles with the global stats["proposals"]
        metrics["proposals"].inc(
            len(outs) * max(int(getattr(scfg, "population", 1)), 1))
        if multiproc:
            scal = np.asarray(exchange(put_shd(scal)))
        for i in range(n_islands):
            loss = float(scal[i, 0])
            delta = loss - cur[i]
            uniform = rngs[i].random() if T > 0.0 else None
            accepted = anneal.accept(delta, T, uniform)
            if accepted:
                if delta > 0.0:
                    stats["uphill_accepts"] += 1
                    if i in outs:
                        metrics["uphill"].inc()
                if i in outs:   # count local islands only (see proposals)
                    metrics["accepts"].inc()
                cur[i] = loss
                n_accept[i] += 1
                if i in outs:
                    fq_loc[i] = outs[i][3]
                    t_loc[i] = _tree_update(t_loc[i], u_dev[i], outs[i][4])
                if loss < best[i]:
                    best[i] = loss
                    if i in outs:
                        bt_loc[i] = t_loc[i]
                        bfq_loc[i] = fq_loc[i]
            histories[i].append((step, loss, float(scal[i, 1]),
                                 float(scal[i, 2]), accepted))
        if migrate_every and n_islands > 1 and step % migrate_every == 0:
            t_st = gather_island_states(t_loc, mesh, n_islands)
            fq_st = gather_island_states(fq_loc, mesh, n_islands)
            bt_st = gather_island_states(bt_loc, mesh, n_islands)
            bfq_st = gather_island_states(bfq_loc, mesh, n_islands)
            t_st, fq_st, bt_st, bfq_st, did = migrate_mapped(
                put_shd(np.asarray(best, np.float32)),
                put_shd(np.asarray(cur, np.float32)),
                t_st, fq_st, bt_st, bfq_st)
            t_loc = scatter_island_states(t_st, local)
            fq_loc = scatter_island_states(fq_st, local)
            bt_loc = scatter_island_states(bt_st, local)
            bfq_loc = scatter_island_states(bfq_st, local)
            if bool(np.asarray(did)):
                # replay the decision on the host floats (identical f32
                # comparisons to the ones the device just made)
                src = int(np.argmin(np.asarray(best, np.float32)))
                dst = int(np.argmax(np.asarray(cur, np.float32)))
                cur[dst] = best[src]
                if best[src] < best[dst]:
                    best[dst] = best[src]
                stats["migrations"] += 1
                if pid0:   # every host replays the decision; count it once
                    metrics["migrations"].inc()
        step_span.__exit__(None, None, None)
        metrics["best"].set(min(best))
        metrics["temp"].set(T)
        if scfg.log_every and step % scfg.log_every == 0:
            rate = sum(n_accept) / (step * n_islands)
            obs.emit("search", step=step, best=f"{min(best):.5f}",
                     accept=f"{rate:.2%}", T=f"{T:.4g}",
                     elapsed_s=f"{run_span.elapsed():.1f}", mode="mapped")

    elite = int(np.argmin(np.asarray(best, np.float32)))
    bt_st = gather_island_states(bt_loc, mesh, n_islands)
    bfq_st = gather_island_states(bfq_loc, mesh, n_islands)

    def fetch_body(bt, bfq):
        strip = lambda tr: jax.tree.map(lambda x: x[0], tr)  # noqa: E731
        return (elite_broadcast(strip(bt), elite, "data"),
                elite_broadcast(strip(bfq), elite, "data"))

    best_t, best_fq = jax.jit(shard_map(
        fetch_body, mesh=mesh, in_specs=(P("data"),) * 2,
        out_specs=(P(), P()), check_vma=False))(bt_st, bfq_st)
    # localize: the result contract is host-local arrays, same as sequential
    best_t = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), best_t)
    best_fq = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), best_fq)

    run_span.__exit__(None, None, None)
    # monotonic clock (run_span.dur): wall time steps backwards under NTP
    stats["proposals_per_sec"] = stats["proposals"] / max(run_span.dur, 1e-9)
    return SearchResult(
        params_q=adapter.install(env["params_base"], best_fq),
        transforms=best_t,
        history=histories[elite],
        accept_rate=n_accept[elite] / max(scfg.steps, 1),
        final_loss=best[elite],
        initial_loss=loss0,
        island_histories=histories,
        stats=stats,
    )
