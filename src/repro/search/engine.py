"""Population × island annealed discrete search (the Algorithm 1 engine).

One engine, three nested degrees of freedom, each defaulting to the paper's
single-chain hill climb:

- population K: K candidate transforms for the step's unit, evaluated in ONE
  vmap-batched transform→fake-quant→forward→loss program (the calibration
  forward is amortized K ways); the per-step move is the argmin candidate.
- temperature T: Metropolis acceptance of the chosen candidate under an
  annealing schedule; T=0 is the strict accept-iff-better rule.
- islands: independent chains with per-island counter-based key streams and
  elite migration on a fixed cadence (``repro.search.islands``).

Bit-for-bit contract: at ``population=1, islands=1, temperature=0`` the
engine's proposal keys, unit picks, jitted programs and accept decisions are
EXACTLY the legacy ``core/search.py`` loop's, so the accepted-move trajectory
reproduces the paper configuration unchanged (pinned by
``tests/test_search_engine.py``).

Multi-host note: proposals come from counter-based ``jax.random`` keys and
unit picks/accept draws from a host-side ``default_rng(seed)`` stream, so
every host replays the same chain and only the (all-reduced) scalar loss
feeds the accept decision. Islands run sequentially in-process here; the
mesh-mapped execution (one island per data-axis shard,
``islands.elite_over_mesh`` as the per-migration scalar exchange) is the
designed-for multi-host path, not yet wired (ROADMAP).
"""
from __future__ import annotations

import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import invariance as inv
from repro.core import objective as obj
from repro.models.model import forward
from repro.search import anneal
from repro.search.islands import IslandState, make_island_streams, migrate
from repro.search.population import candidate_keys, stack_trees, take_tree

__all__ = ["run_population_search"]


def _tree_slice(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _tree_update(tree, i, new):
    return jax.tree.map(lambda x, n: x.at[i].set(n), tree, new)


def run_population_search(
    params_fp: dict,
    params_base: dict,
    cfg,
    qcfg,
    calib_tokens: jnp.ndarray,
    scfg,
    adapter,
    forward_kwargs: Optional[dict] = None,
):
    """Run the engine; returns a ``core.search.SearchResult``.

    ``params_fp`` / ``params_base`` follow the ``core.search.run_search``
    contract (FP reference model; base-method continuous-domain FFN weights
    with everything else already fake-quantized).
    """
    from repro.core.search import SearchResult  # front-end owns the dataclass

    fwd_kw = forward_kwargs or {}
    n_match = min(scfg.n_match_layers, cfg.n_layers)
    K = max(int(getattr(scfg, "population", 1)), 1)
    n_islands = max(int(getattr(scfg, "islands", 1)), 1)
    migrate_every = int(getattr(scfg, "migrate_every", 0))
    fused = bool(getattr(scfg, "fused_kernel", False))
    if fused and not hasattr(adapter, "transform_quant_unit"):
        warnings.warn(
            f"fused_kernel=True but adapter {type(adapter).__name__} has no "
            f"transform_quant_unit; falling back to the unfused "
            f"transform->quantize path", stacklevel=2)
        fused = False

    base = adapter.base_stack(params_base)
    proposer = getattr(adapter, "propose", None) or (
        lambda key, t, pcfg: inv.propose(key, t, pcfg))

    # identity transforms + initial fake-quant of every unit (per-unit slices
    # hit quant_unit so the ndim>=2 "skip biases" check stays correct)
    t0 = inv.identity_transform(adapter.f_dim)
    transforms0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (adapter.n_units,) + x.shape).copy(), t0)
    fq0 = jax.vmap(lambda b: adapter.quant_unit(b, qcfg))(base)

    # reference forward (FP model)
    logits_fp, hidden_fp = forward(params_fp, cfg, calib_tokens,
                                   collect_hidden=True, **fwd_kw)
    hidden_fp = jax.lax.stop_gradient(hidden_fp[:n_match]) if n_match else None
    logits_fp = jax.lax.stop_gradient(logits_fp)

    def eval_stack_fn(fq):
        params_q = adapter.install(params_base, fq)
        logits, hidden = forward(params_q, cfg, calib_tokens,
                                 collect_hidden=True, **fwd_kw)
        if scfg.objective == "kl":
            ce = obj.calib_kl(logits, logits_fp, cfg.vocab_size)
        else:
            ce = obj.calib_ce(logits, calib_tokens, cfg.vocab_size)
        mse = (obj.activation_mse(hidden, hidden_fp, n_match)
               if n_match else jnp.float32(0.0))
        return ce, mse

    eval_stack = jax.jit(eval_stack_fn)

    ce0, mse0 = map(float, eval_stack(fq0))
    alpha = obj.resolve_alpha(ce0, mse0, scfg.ce_weight) if n_match else 0.0
    loss0 = ce0 + alpha * float(mse0)

    def quant_candidate(t_new, u):
        if fused:
            return adapter.transform_quant_unit(base, t_new, u, qcfg)
        unit = adapter.transform_unit(base, t_new, u)
        return adapter.quant_unit(unit, qcfg)

    @jax.jit
    def step_single(key, transforms, fq_stack, u):
        # EXACTLY the legacy step: one proposal, unbatched evaluation — keeps
        # the K=1 trajectory bit-identical to the original hill climb.
        k_prop, _ = jax.random.split(key)
        t_u = _tree_slice(transforms, u)
        t_new = proposer(k_prop, inv.FFNTransform(*t_u), scfg.proposal)
        unit = adapter.transform_unit(base, t_new, u)
        unit_fq = adapter.quant_unit(unit, qcfg)
        fq_new = _tree_update(fq_stack, u, unit_fq)
        ce, mse = eval_stack(fq_new)
        loss = ce + alpha * mse
        return loss, ce, mse, fq_new, t_new

    @jax.jit
    def step_population(key, transforms, fq_stack, u):
        keys = candidate_keys(key, K)
        t_u = inv.FFNTransform(*_tree_slice(transforms, u))
        cands = [proposer(keys[i], t_u, scfg.proposal) for i in range(K)]
        fq_news = [_tree_update(fq_stack, u, quant_candidate(t, u))
                   for t in cands]
        fq_batch = stack_trees(fq_news)          # (K, n_units, ...)
        ce, mse = jax.vmap(eval_stack_fn)(fq_batch)  # ONE batched forward
        loss = ce + alpha * mse
        i = jnp.argmin(loss)
        return (loss[i], ce[i], mse[i], take_tree(fq_batch, i),
                take_tree(stack_trees(cands), i))

    step_fn = step_single if (K == 1 and not fused) else step_population
    schedule = anneal.temperature_schedule(
        getattr(scfg, "anneal", "geometric"),
        float(getattr(scfg, "temperature", 0.0)), scfg.steps)

    islands = []
    for i in range(n_islands):
        rng, key = make_island_streams(scfg.seed, i)
        islands.append(IslandState(
            index=i, rng=rng, key=key, transforms=transforms0, fq_stack=fq0,
            current_loss=loss0, best_loss=loss0, best_transforms=transforms0,
            best_fq=fq0, history=[(0, loss0, ce0, float(mse0), True)]))

    stats = {"migrations": 0, "uphill_accepts": 0,
             "proposals": scfg.steps * K * n_islands, "fused": fused}
    t_start = time.time()
    for step in range(1, scfg.steps + 1):
        T = schedule(step)
        for isl in islands:
            isl.key, sub = jax.random.split(isl.key)
            u = jnp.int32(isl.rng.integers(adapter.n_units))
            loss, ce, mse, fq_new, t_new = step_fn(
                sub, isl.transforms, isl.fq_stack, u)
            loss = float(loss)
            delta = loss - isl.current_loss
            uniform = isl.rng.random() if T > 0.0 else None
            accepted = anneal.accept(delta, T, uniform)
            if accepted:
                # strictly-worse moves only (delta == 0 is lateral, not
                # uphill), counted as a Python int — not an accumulated
                # numpy bool
                if delta > 0.0:
                    stats["uphill_accepts"] += 1
                isl.current_loss = loss
                isl.fq_stack = fq_new
                isl.transforms = _tree_update(isl.transforms, u, t_new)
                isl.n_accept += 1
                if loss < isl.best_loss:
                    isl.best_loss = loss
                    isl.best_transforms = isl.transforms
                    isl.best_fq = isl.fq_stack
            isl.history.append((step, loss, float(ce), float(mse), accepted))
        if migrate_every and n_islands > 1 and step % migrate_every == 0:
            stats["migrations"] += migrate(islands)
        if scfg.log_every and step % scfg.log_every == 0:
            best = min(s.best_loss for s in islands)
            rate = sum(s.n_accept for s in islands) / (step * n_islands)
            print(f"[search] step={step} best={best:.5f} accept={rate:.2%} "
                  f"T={T:.4g} ({(time.time() - t_start):.1f}s)")

    elite = min(islands, key=lambda s: s.best_loss)
    stats["proposals_per_sec"] = stats["proposals"] / max(
        time.time() - t_start, 1e-9)
    return SearchResult(
        params_q=adapter.install(params_base, elite.best_fq),
        transforms=elite.best_transforms,
        history=elite.history,
        accept_rate=elite.n_accept / max(scfg.steps, 1),
        final_loss=elite.best_loss,
        initial_loss=loss0,
        island_histories=[s.history for s in islands],
        stats=stats,
    )
