"""Population × island annealed discrete search (the Algorithm 1 engine).

One engine, three nested degrees of freedom, each defaulting to the paper's
single-chain hill climb:

- population K: K candidate transforms for the step's unit. v2 memory model
  (``SearchConfig(install="unit")``, the default): the engine carries ONE
  fake-quant stack plus a K × *unit* candidate buffer and installs only the
  touched unit per evaluation via ``jax.lax.dynamic_update_slice`` tree
  surgery (``repro.search.install``) — peak memory is stack + K × unit.
  ``install="stack"`` keeps the v1 semantics (K full stacks through one
  ``vmap``-batched program) for A/B benchmarking.
- temperature T: Metropolis acceptance of the chosen candidate under an
  annealing schedule; T=0 is the strict accept-iff-better rule.
- islands: independent chains with per-island counter-based key streams and
  elite migration on a fixed cadence (``repro.search.islands``). With
  ``shard_calib=True`` each island climbs on its OWN contiguous slice of the
  calibration batch (``data.calib.shard_calibration``) — true data-parallel
  calibration; islands exchange only scalar objective estimates at
  migration.

The objective is pluggable (``core.objective``): ``SearchConfig.objective``
takes a registry name ("ce", "kl", "swd_actmatch", "saliency_ce") or an
``Objective`` instance; the engine combines ``(primary, aux)`` as
``loss = primary + α · aux`` with α resolved from the step-0 full-batch
values. A tried-point tabu memory (``SearchConfig(tabu=N)``,
``repro.search.tabu``) replays cached scalars for proposals already
evaluated at the current chain state instead of paying the device forward.

Bit-for-bit contract: at ``population=1, islands=1, temperature=0`` under
the default objective (tabu off, calibration replicated) the engine's
proposal keys, unit picks, jitted programs and accept decisions are EXACTLY
the legacy ``core/search.py`` loop's, so the accepted-move trajectory
reproduces the paper configuration unchanged (pinned by
``tests/test_search_engine.py``, now through the ``repro.search.run`` front
door).

Execution modes:

- sequential (default): islands run one after another in-process — the
  reference semantics, and the only mode a 1-device host can run.
- ``mapped=True``: one island per shard of a 1-D ("data",) mesh over ALL
  global devices. Every process replays every island's HOST streams (unit
  picks, accept draws — cheap scalars), so the accept logic stays on the
  host exactly as in sequential mode; only the expensive proposal
  evaluation runs on-device, one island per shard, and the per-migration
  traffic is one scalar ``argmin_allgather`` plus the winner's state via
  ``elite_broadcast``. The mapped trajectory is pinned BIT-FOR-BIT equal to
  the sequential island loop because both lanes call the SAME per-island
  step programs (the CI ``distributed`` lane drives 2 real processes
  through ``repro.launch.dist_smoke``).
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import invariance as inv
from repro.core import objective as obj
from repro.models.model import forward
from repro.search import anneal
from repro.search.install import (stack_unit_batch, tree_bytes,
                                  tree_install_unit)
from repro.search.islands import (IslandState, make_island_streams, migrate,
                                  migrate_on_mesh)
from repro.search.population import candidate_keys, stack_trees, take_tree
from repro.search.tabu import TabuMemory, transform_bytes

__all__ = ["run_population_search"]


def _search_metrics():
    """Instrument handles on the process registry (get-or-create, so a
    registry ``reset()`` between runs keeps these valid)."""
    reg = obs.get_registry()
    return {
        "proposals": reg.counter(
            "search_proposals_total", "Candidate transforms proposed"),
        "accepts": reg.counter(
            "search_accepts_total", "Moves accepted by the Metropolis rule"),
        "uphill": reg.counter(
            "search_uphill_accepts_total",
            "Accepted strictly-worse (uphill) moves"),
        "migrations": reg.counter(
            "search_migrations_total", "Elite island migrations applied"),
        "tabu": reg.counter(
            "search_tabu_hits_total",
            "Proposals deduplicated by the tried-point memory"),
        "best": reg.gauge(
            "search_objective_best", "Best combined objective seen so far"),
        "temp": reg.gauge(
            "search_temperature", "Annealing temperature at the last step"),
        "step": reg.histogram(
            "search_step_seconds", "Wall time of one full search step"),
        "eval": reg.histogram(
            "search_eval_seconds",
            "Proposal evaluation latency (dispatch + loss sync)"),
    }


def _tree_slice(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _tree_update(tree, i, new):
    return jax.tree.map(lambda x, n: x.at[i].set(n), tree, new)


def _live_bytes() -> int:
    return int(sum(getattr(a, "nbytes", 0) for a in jax.live_arrays()))


def _resolve_install(scfg) -> str:
    mode = str(getattr(scfg, "install", "unit"))
    if mode not in ("unit", "stack"):
        raise ValueError(
            f"SearchConfig.install must be 'unit' or 'stack', got {mode!r}")
    return mode


def run_population_search(
    params_fp: dict,
    params_base: dict,
    cfg,
    qcfg,
    calib_tokens: jnp.ndarray,
    scfg,
    adapter,
    forward_kwargs: Optional[dict] = None,
):
    """Deprecated alias of the engine loop — call ``repro.search.run``.

    Kept as a thin shim so pre-v2 callers keep working; the front door adds
    objective resolution and hybrid two-phase dispatch on top of this loop.
    """
    warnings.warn(
        "repro.search.engine.run_population_search is deprecated; use "
        "repro.search.run(...)", DeprecationWarning, stacklevel=2)
    return _run_engine(params_fp, params_base, cfg, qcfg, calib_tokens,
                       scfg, adapter, forward_kwargs)


def _run_engine(
    params_fp: dict,
    params_base: dict,
    cfg,
    qcfg,
    calib_tokens: jnp.ndarray,
    scfg,
    adapter,
    forward_kwargs: Optional[dict] = None,
):
    """Run the engine; returns a ``core.search.SearchResult``.

    ``params_fp`` / ``params_base`` follow the ``core.search.run_search``
    contract (FP reference model; base-method continuous-domain FFN weights
    with everything else already fake-quantized).
    """
    from repro.core.search import SearchResult  # front-end owns the dataclass
    from repro.data.calib import shard_calibration

    fwd_kw = forward_kwargs or {}
    n_match = min(scfg.n_match_layers, cfg.n_layers)
    K = max(int(getattr(scfg, "population", 1)), 1)
    n_islands = max(int(getattr(scfg, "islands", 1)), 1)
    migrate_every = int(getattr(scfg, "migrate_every", 0))
    mapped = bool(getattr(scfg, "mapped", False))
    fused = bool(getattr(scfg, "fused_kernel", False))
    install_mode = _resolve_install(scfg)
    tabu_cap = int(getattr(scfg, "tabu", 0))
    shard_calib = bool(getattr(scfg, "shard_calib", False))
    measure = bool(getattr(scfg, "measure_memory", False))
    objv = obj.get_objective(getattr(scfg, "objective", "ce"))
    if fused and not hasattr(adapter, "transform_quant_unit"):
        warnings.warn(
            f"fused_kernel=True but adapter {type(adapter).__name__} has no "
            f"transform_quant_unit; falling back to the unfused "
            f"transform->quantize path", stacklevel=2)
        fused = False
    if tabu_cap and mapped:
        raise ValueError(
            "tabu memory needs the host-synchronous sequential lane "
            "(candidate fingerprints are host state); mapped=True cannot "
            "combine with tabu>0")

    base = adapter.base_stack(params_base)
    proposer = getattr(adapter, "propose", None) or (
        lambda key, t, pcfg: inv.propose(key, t, pcfg))

    # identity transforms + initial fake-quant of every unit (per-unit slices
    # hit quant_unit so the ndim>=2 "skip biases" check stays correct)
    t0 = inv.identity_transform(adapter.f_dim)
    transforms0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (adapter.n_units,) + x.shape).copy(), t0)
    fq0 = jax.vmap(lambda b: adapter.quant_unit(b, qcfg))(base)

    # reference forward (FP model) on the FULL calibration batch; per-island
    # slices view into these (batch axis 0 for tokens/logits, axis 1 for the
    # (L, B, S, D) hidden taps)
    logits_fp, hidden_fp = forward(params_fp, cfg, calib_tokens,
                                   collect_hidden=True, **fwd_kw)
    hidden_fp = jax.lax.stop_gradient(hidden_fp[:n_match]) if n_match else None
    logits_fp = jax.lax.stop_gradient(logits_fp)

    def make_env(tokens, lfp, hfp):
        return obj.ObjectiveEnv(calib=tokens, logits_fp=lfp, hidden_fp=hfp,
                                vocab_size=cfg.vocab_size, n_match=n_match,
                                ce_weight=scfg.ce_weight)

    env_global = make_env(calib_tokens, logits_fp, hidden_fp)
    if shard_calib:
        slices = shard_calibration(calib_tokens, n_islands)
        bounds = np.cumsum([0] + [int(s.shape[0]) for s in slices])
        envs = [make_env(slices[i],
                         logits_fp[bounds[i]:bounds[i + 1]],
                         (hidden_fp[:, bounds[i]:bounds[i + 1]]
                          if n_match else None))
                for i in range(n_islands)]
    else:
        envs = [env_global] * n_islands

    def make_eval(env):
        state = objv.prepare(env)

        def eval_stack_fn(fq):
            params_q = adapter.install(params_base, fq)
            logits, hidden = forward(params_q, cfg, env.calib,
                                     collect_hidden=True, **fwd_kw)
            return objv.evaluate(logits, hidden, state, env)

        return eval_stack_fn

    eval_global = make_eval(env_global)
    p0, a0 = map(float, jax.jit(eval_global)(fq0))
    alpha = float(objv.resolve_mix(p0, a0, env_global))
    loss0 = p0 + alpha * a0

    def quant_candidate(t_new, u):
        if fused:
            return adapter.transform_quant_unit(base, t_new, u, qcfg)
        unit = adapter.transform_unit(base, t_new, u)
        return adapter.quant_unit(unit, qcfg)

    # ---- per-island step programs ----------------------------------------
    # legacy single path: EXACTLY the pre-engine step — one proposal,
    # unbatched evaluation in ONE jitted program. This is the bit-for-bit
    # anchor; any K>1 / fused / tabu request takes the staged v2 pipeline.
    staged = (K > 1) or fused or (tabu_cap > 0)
    peak = {"bytes": 0, "batch_bytes": 0}

    def make_single_step(eval_stack_fn):
        def step_body_single(key, transforms, fq_stack, u):
            k_prop, _ = jax.random.split(key)
            t_u = _tree_slice(transforms, u)
            t_new = proposer(k_prop, inv.FFNTransform(*t_u), scfg.proposal)
            unit = adapter.transform_unit(base, t_new, u)
            unit_fq = adapter.quant_unit(unit, qcfg)
            fq_new = _tree_update(fq_stack, u, unit_fq)
            p, a = eval_stack_fn(fq_new)
            loss = p + alpha * a
            return loss, p, a, fq_new, t_new

        return jax.jit(step_body_single)

    # staged v2 pipeline: propose / build / eval / pick are SEPARATE jitted
    # stages so the K-candidate buffer is a real set of device arrays between
    # stages — ``jax.live_arrays()`` then measures the memory model honestly
    # (stack + K × unit for install="unit", (K+1) × stack for "stack").
    def propose_body(key, transforms, u):
        keys = candidate_keys(key, K)
        t_u = inv.FFNTransform(*_tree_slice(transforms, u))
        cands = [proposer(keys[i], t_u, scfg.proposal) for i in range(K)]
        return stack_trees(cands)

    propose_fn = jax.jit(propose_body)

    def build_units_body(cands, u):
        units = [quant_candidate(inv.FFNTransform(*_tree_slice(cands, i)), u)
                 for i in range(K)]
        return stack_unit_batch(units)

    def build_stacks_body(cands, fq_stack, u):
        units = [quant_candidate(inv.FFNTransform(*_tree_slice(cands, i)), u)
                 for i in range(K)]
        return stack_trees([tree_install_unit(fq_stack, u, un)
                            for un in units])

    build_units_fn = jax.jit(build_units_body)
    build_stacks_fn = jax.jit(build_stacks_body)

    def make_staged_step(eval_stack_fn):
        if install_mode == "unit":
            def eval_body(batch, fq_stack, u):
                def body(unit_fq):
                    return eval_stack_fn(
                        tree_install_unit(fq_stack, u, unit_fq))
                return jax.lax.map(body, batch)

            def pick_body(cands, batch, fq_stack, u, p_vec, a_vec):
                loss = p_vec + alpha * a_vec
                i = jnp.argmin(loss)
                fq_new = tree_install_unit(fq_stack, u, take_tree(batch, i))
                return (loss[i], p_vec[i], a_vec[i], fq_new,
                        inv.FFNTransform(*_tree_slice(cands, i)), loss)
        else:
            def eval_body(batch, fq_stack, u):
                del fq_stack, u
                return jax.vmap(eval_stack_fn)(batch)

            def pick_body(cands, batch, fq_stack, u, p_vec, a_vec):
                del fq_stack, u
                loss = p_vec + alpha * a_vec
                i = jnp.argmin(loss)
                return (loss[i], p_vec[i], a_vec[i], take_tree(batch, i),
                        inv.FFNTransform(*_tree_slice(cands, i)), loss)

        eval_fn = jax.jit(eval_body)
        pick_fn = jax.jit(pick_body)

        def step(key, transforms, fq_stack, u):
            cands = propose_fn(key, transforms, u)
            if install_mode == "unit":
                batch = build_units_fn(cands, u)
            else:
                batch = build_stacks_fn(cands, fq_stack, u)
            if measure:
                jax.block_until_ready(batch)
                peak["bytes"] = max(peak["bytes"], _live_bytes())
                peak["batch_bytes"] = max(peak["batch_bytes"],
                                          tree_bytes(batch))
            p_vec, a_vec = eval_fn(batch, fq_stack, u)
            out = pick_fn(cands, batch, fq_stack, u, p_vec, a_vec)
            return out[:5] + (out[5], p_vec, a_vec)

        return step

    def make_step_fn(eval_stack_fn):
        """Host-callable step: (key, transforms, fq_stack, u) ->
        (loss, primary, aux, fq_new, t_new[, cands, loss_vec])."""
        if staged:
            return make_staged_step(eval_stack_fn)
        single = make_single_step(eval_stack_fn)

        def step(key, transforms, fq_stack, u):
            return single(key, transforms, fq_stack, u)

        return step

    eval_fns = ([make_eval(e) for e in envs] if shard_calib
                else [eval_global] * n_islands)
    if shard_calib:
        step_fns = [make_step_fn(f) for f in eval_fns]
        # per-island step-0 baselines on each island's OWN slice (1 island
        # == the full batch == bitwise the replicated baseline)
        loss0s, p0s, a0s = [], [], []
        for f in eval_fns:
            pi0, ai0 = map(float, jax.jit(f)(fq0))
            p0s.append(pi0)
            a0s.append(ai0)
            loss0s.append(pi0 + alpha * ai0)
    else:
        shared = make_step_fn(eval_global)
        step_fns = [shared] * n_islands
        loss0s = [loss0] * n_islands
        p0s = [p0] * n_islands
        a0s = [a0] * n_islands

    schedule = anneal.temperature_schedule(
        getattr(scfg, "anneal", "geometric"),
        float(getattr(scfg, "temperature", 0.0)), scfg.steps)

    stats = {"migrations": 0, "uphill_accepts": 0,
             "proposals": scfg.steps * K * n_islands, "fused": fused,
             "mapped": mapped, "objective": objv.name,
             "install": install_mode, "tabu_hits": 0,
             "shard_calib": shard_calib}
    metrics = _search_metrics()
    metrics["best"].set(loss0)

    if mapped:
        return _run_mapped_islands(
            SearchResult, adapter, scfg, params_base, step_fns, schedule,
            stats, transforms0, fq0, loss0s, p0s, a0s, n_islands,
            migrate_every, metrics, objv.name)

    if measure:
        baseline = _live_bytes()
        peak["bytes"] = baseline

    islands = []
    tabus = []
    for i in range(n_islands):
        rng, key = make_island_streams(scfg.seed, i)
        islands.append(IslandState(
            index=i, rng=rng, key=key, transforms=transforms0, fq_stack=fq0,
            current_loss=loss0s[i], best_loss=loss0s[i],
            best_transforms=transforms0, best_fq=fq0,
            history=[(0, loss0s[i], p0s[i], a0s[i], True)]))
        tabus.append(TabuMemory(tabu_cap) if tabu_cap else None)

    # on a full-K tabu hit the device eval is skipped; if the Metropolis rule
    # then ACCEPTS a cached (previously rejected, T>0) move, only its unit is
    # rebuilt and installed — one quant, no calibration forward
    def rebuild_body(cands, fq_stack, u, i):
        t_new = inv.FFNTransform(*_tree_slice(cands, i))
        fq_new = tree_install_unit(fq_stack, u,
                                   quant_candidate(t_new, u))
        return fq_new, t_new

    rebuild_fn = jax.jit(rebuild_body)

    with obs.trace_span("search.run", mode="sequential",
                        islands=n_islands, population=K) as run_span:
        for step in range(1, scfg.steps + 1):
            T = schedule(step)
            with obs.trace_span("search.step", step=step,
                                hist=metrics["step"]):
                for isl in islands:
                    mem = tabus[isl.index]
                    isl.key, sub = jax.random.split(isl.key)
                    u = jnp.int32(isl.rng.integers(adapter.n_units))
                    skipped = False
                    cands = fps = None
                    if mem is not None:
                        cands = propose_fn(sub, isl.transforms, u)
                        cand_bytes = [
                            transform_bytes(_tree_slice(cands, i))
                            for i in range(K)]
                        fps = [mem.fingerprint(int(u), cb)
                               for cb in cand_bytes]
                        hits_before = mem.hits
                        cached = [mem.lookup(fp) for fp in fps]
                        new_hits = mem.hits - hits_before
                        if new_hits:
                            stats["tabu_hits"] += new_hits
                            metrics["tabu"].inc(new_hits)
                        skipped = all(c is not None for c in cached)
                    with obs.trace_span("search.eval",
                                        hist=metrics["eval"]):
                        if skipped:
                            # replay: no device eval, no extra PRNG draw
                            # (the step key was spent proposing, exactly as
                            # on the eval path)
                            ci = int(np.argmin([c[0] for c in cached]))
                            loss, p, a = cached[ci]
                            fq_new = t_new = None
                        else:
                            out = step_fns[isl.index](
                                sub, isl.transforms, isl.fq_stack, u)
                            loss, p, a, fq_new, t_new = out[:5]
                            loss = float(loss)   # the device sync
                            if mem is not None:
                                # cache every candidate's device-computed
                                # scalars for exact replay on a later hit
                                loss_vec = np.asarray(out[5], np.float32)
                                p_vec = np.asarray(out[6], np.float32)
                                a_vec = np.asarray(out[7], np.float32)
                                for i in range(K):
                                    mem.record(fps[i], float(loss_vec[i]),
                                               float(p_vec[i]),
                                               float(a_vec[i]))
                            if measure:
                                peak["bytes"] = max(peak["bytes"],
                                                    _live_bytes())
                    metrics["proposals"].inc(K, objective=objv.name)
                    delta = loss - isl.current_loss
                    uniform = isl.rng.random() if T > 0.0 else None
                    accepted = anneal.accept(delta, T, uniform)
                    if accepted:
                        if skipped:
                            fq_new, t_new = rebuild_fn(
                                cands, isl.fq_stack, u, jnp.int32(ci))
                        # strictly-worse moves only (delta == 0 is lateral,
                        # not uphill), counted as a Python int — not an
                        # accumulated numpy bool
                        if delta > 0.0:
                            stats["uphill_accepts"] += 1
                            metrics["uphill"].inc()
                        metrics["accepts"].inc()
                        isl.current_loss = loss
                        isl.fq_stack = fq_new
                        isl.transforms = _tree_update(isl.transforms, u,
                                                      t_new)
                        isl.n_accept += 1
                        if mem is not None:
                            idx = ci if skipped else None
                            if idx is None:
                                # which candidate won? match by bytes
                                tb = transform_bytes(t_new)
                                idx = cand_bytes.index(tb)
                            mem.advance(cand_bytes[idx])
                        if loss < isl.best_loss:
                            isl.best_loss = loss
                            isl.best_transforms = isl.transforms
                            isl.best_fq = isl.fq_stack
                    isl.history.append(
                        (step, loss, float(p), float(a), accepted))
                if migrate_every and n_islands > 1 \
                        and step % migrate_every == 0:
                    if tabu_cap:
                        src = min(islands, key=lambda s: s.best_loss)
                        dst = max(islands, key=lambda s: s.current_loss)
                        will = (src is not dst
                                and src.best_loss < dst.current_loss)
                    n_migrated = migrate(islands)
                    stats["migrations"] += n_migrated
                    metrics["migrations"].inc(n_migrated)
                    if tabu_cap and n_migrated and will:
                        tabus[dst.index].adopt_digest(tabus[src.index])
            metrics["best"].set(min(s.best_loss for s in islands))
            metrics["temp"].set(T)
            if scfg.log_every and step % scfg.log_every == 0:
                best = min(s.best_loss for s in islands)
                rate = sum(s.n_accept for s in islands) / (step * n_islands)
                obs.emit("search", step=step, best=f"{best:.5f}",
                         accept=f"{rate:.2%}", T=f"{T:.4g}",
                         elapsed_s=f"{run_span.elapsed():.1f}")

    elite = min(islands, key=lambda s: s.best_loss)
    # monotonic clock (run_span.dur): wall time steps backwards under NTP
    stats["proposals_per_sec"] = stats["proposals"] / max(run_span.dur, 1e-9)
    if measure:
        stats["peak_live_bytes"] = max(peak["bytes"] - baseline, 0)
        stats["stack_bytes"] = tree_bytes(fq0)
        stats["candidate_batch_bytes"] = peak["batch_bytes"]
    return SearchResult(
        params_q=adapter.install(params_base, elite.best_fq),
        transforms=elite.best_transforms,
        history=elite.history,
        accept_rate=elite.n_accept / max(scfg.steps, 1),
        final_loss=elite.best_loss,
        initial_loss=loss0s[elite.index],
        island_histories=[s.history for s in islands],
        stats=stats,
    )


# ---------------------------------------------------------------------------
# mapped mode: one island per shard of the ("data",) mesh
# ---------------------------------------------------------------------------

def _run_mapped_islands(SearchResult, adapter, scfg, params_base, step_fns,
                        schedule, stats, transforms0, fq0, loss0s, p0s, a0s,
                        n_islands, migrate_every, metrics, obj_name):
    """The mapped island loop: one island per shard of the ("data",) mesh.

    Split of responsibilities, chosen so "bit-for-bit equal to sequential"
    is a property of the construction rather than a hope about the compiler:

    - the per-island STEP (propose → transform → fake-quant → forward → loss)
      runs the SAME per-island step program the sequential engine runs (the
      legacy single-jit body, or the staged v2 propose/build/eval/pick
      stages), with the island's state committed to its shard's device — XLA
      generates identical code for identical programs, so the per-step
      scalars come out bit-identical island by island. (Running the step
      *inside* shard_map instead was measurably NOT bit-stable: the
      surrounding slice/gather graph perturbs how XLA fuses the loss
      reductions, and ``optimization_barrier`` does not fence it off.)
    - everything CROSS-island runs inside ``shard_map`` over the island axis
      and is pure data movement, which is exact: the per-step scalar
      exchange (an all-gather of each shard's (loss, primary, aux) row), and
      the per-migration elite exchange — ``argmin_allgather`` for the scalar
      race, ``elite_broadcast`` for the winner's state, a masked select for
      the splice (``islands.migrate_on_mesh``).
    - control stays on the host: every process replays every island's host
      streams (unit picks, accept uniforms — cheap scalars), so the accept
      logic and histories are computed identically everywhere, and each
      process steps only the islands whose shard devices it owns.

    Under ``shard_calib=True`` each island's step program closes over its
    own calibration slice (``step_fns[i]``), so the migration race compares
    per-slice objective estimates — the only cross-island objective traffic.

    Under a multi-process ``jax.distributed`` runtime the same loop runs
    unchanged: hosts step their local islands independently and meet only at
    the scalar exchange and migrations (the CI ``distributed`` lane pins 2
    processes against the single-process sequential result).
    """
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.dist import runtime
    from repro.dist.compat import shard_map
    from repro.dist.collectives import elite_broadcast
    from repro.search.islands import gather_island_states, scatter_island_states

    devs = jax.devices()
    if n_islands != len(devs):
        raise ValueError(
            f"mapped=True runs one island per device shard: islands="
            f"{n_islands} but the mesh has {len(devs)} global devices "
            f"(match --islands to the device count, or force devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    mesh = Mesh(np.array(devs), ("data",))
    shd = NamedSharding(mesh, P("data"))
    pid = jax.process_index()
    local = {i: d for i, d in enumerate(devs) if d.process_index == pid}
    multiproc = jax.process_count() > 1

    # per-LOCAL-island state, committed to the island's shard device (the
    # cross-host stacked layout only materializes for migrations/fetch)
    t_loc = {i: jax.device_put(transforms0, d) for i, d in local.items()}
    fq_loc = {i: jax.device_put(fq0, d) for i, d in local.items()}
    bt_loc = dict(t_loc)
    bfq_loc = dict(fq_loc)

    exchange = jax.jit(shard_map(
        lambda rows: jax.lax.all_gather(rows[0], "data"),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(), check_vma=False))

    migrate_mapped = jax.jit(shard_map(
        lambda bl, cl, t, fq, bt, bfq: migrate_on_mesh(
            bl, cl, t, fq, bt, bfq, "data"),
        mesh=mesh,
        in_specs=(P("data"),) * 6,
        out_specs=((P("data"),) * 4) + (P(),),
        check_vma=False))

    def put_shd(x):
        return runtime.global_put(x, shd)

    streams = [make_island_streams(scfg.seed, i) for i in range(n_islands)]
    rngs = [s[0] for s in streams]
    keys = [s[1] for s in streams]
    cur = list(loss0s)
    best = list(loss0s)
    n_accept = [0] * n_islands
    histories = [[(0, loss0s[i], p0s[i], a0s[i], True)]
                 for i in range(n_islands)]
    K = max(int(getattr(scfg, "population", 1)), 1)

    pid0 = jax.process_index() == 0
    run_span = obs.trace_span("search.run", mode="mapped",
                              islands=n_islands).__enter__()
    for step in range(1, scfg.steps + 1):
        T = schedule(step)
        step_span = obs.trace_span("search.step", step=step,
                                   hist=metrics["step"]).__enter__()
        subs = [None] * n_islands
        us = [None] * n_islands
        for i in range(n_islands):
            # replay EVERY island's streams so hosts stay in lock-step; only
            # the local islands are evaluated
            keys[i], sub = jax.random.split(keys[i])
            subs[i] = sub
            us[i] = int(rngs[i].integers(adapter.n_units))
        with obs.trace_span("search.eval", hist=metrics["eval"]):
            outs = {}
            u_dev = {}
            for i, d in local.items():   # dispatch all, then fetch (async)
                u_dev[i] = jax.device_put(jnp.int32(us[i]), d)
                outs[i] = step_fns[i](jax.device_put(subs[i], d), t_loc[i],
                                      fq_loc[i], u_dev[i])
            scal = np.zeros((n_islands, 3), np.float32)
            for i, out in outs.items():
                scal[i] = [float(out[0]), float(out[1]), float(out[2])]
        # each host counts only its LOCAL islands, so the dist_snapshot sum
        # over hosts reconciles with the global stats["proposals"]
        metrics["proposals"].inc(len(outs) * K, objective=obj_name)
        if multiproc:
            scal = np.asarray(exchange(put_shd(scal)))
        for i in range(n_islands):
            loss = float(scal[i, 0])
            delta = loss - cur[i]
            uniform = rngs[i].random() if T > 0.0 else None
            accepted = anneal.accept(delta, T, uniform)
            if accepted:
                if delta > 0.0:
                    stats["uphill_accepts"] += 1
                    if i in outs:
                        metrics["uphill"].inc()
                if i in outs:   # count local islands only (see proposals)
                    metrics["accepts"].inc()
                cur[i] = loss
                n_accept[i] += 1
                if i in outs:
                    fq_loc[i] = outs[i][3]
                    t_loc[i] = _tree_update(t_loc[i], u_dev[i], outs[i][4])
                if loss < best[i]:
                    best[i] = loss
                    if i in outs:
                        bt_loc[i] = t_loc[i]
                        bfq_loc[i] = fq_loc[i]
            histories[i].append((step, loss, float(scal[i, 1]),
                                 float(scal[i, 2]), accepted))
        if migrate_every and n_islands > 1 and step % migrate_every == 0:
            t_st = gather_island_states(t_loc, mesh, n_islands)
            fq_st = gather_island_states(fq_loc, mesh, n_islands)
            bt_st = gather_island_states(bt_loc, mesh, n_islands)
            bfq_st = gather_island_states(bfq_loc, mesh, n_islands)
            t_st, fq_st, bt_st, bfq_st, did = migrate_mapped(
                put_shd(np.asarray(best, np.float32)),
                put_shd(np.asarray(cur, np.float32)),
                t_st, fq_st, bt_st, bfq_st)
            t_loc = scatter_island_states(t_st, local)
            fq_loc = scatter_island_states(fq_st, local)
            bt_loc = scatter_island_states(bt_st, local)
            bfq_loc = scatter_island_states(bfq_st, local)
            if bool(np.asarray(did)):
                # replay the decision on the host floats (identical f32
                # comparisons to the ones the device just made)
                src = int(np.argmin(np.asarray(best, np.float32)))
                dst = int(np.argmax(np.asarray(cur, np.float32)))
                cur[dst] = best[src]
                if best[src] < best[dst]:
                    best[dst] = best[src]
                stats["migrations"] += 1
                if pid0:   # every host replays the decision; count it once
                    metrics["migrations"].inc()
        step_span.__exit__(None, None, None)
        metrics["best"].set(min(best))
        metrics["temp"].set(T)
        if scfg.log_every and step % scfg.log_every == 0:
            rate = sum(n_accept) / (step * n_islands)
            obs.emit("search", step=step, best=f"{min(best):.5f}",
                     accept=f"{rate:.2%}", T=f"{T:.4g}",
                     elapsed_s=f"{run_span.elapsed():.1f}", mode="mapped")

    elite = int(np.argmin(np.asarray(best, np.float32)))
    bt_st = gather_island_states(bt_loc, mesh, n_islands)
    bfq_st = gather_island_states(bfq_loc, mesh, n_islands)

    def fetch_body(bt, bfq):
        strip = lambda tr: jax.tree.map(lambda x: x[0], tr)  # noqa: E731
        return (elite_broadcast(strip(bt), elite, "data"),
                elite_broadcast(strip(bfq), elite, "data"))

    best_t, best_fq = jax.jit(shard_map(
        fetch_body, mesh=mesh, in_specs=(P("data"),) * 2,
        out_specs=(P(), P()), check_vma=False))(bt_st, bfq_st)
    # localize: the result contract is host-local arrays, same as sequential
    best_t = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), best_t)
    best_fq = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), best_fq)

    run_span.__exit__(None, None, None)
    # monotonic clock (run_span.dur): wall time steps backwards under NTP
    stats["proposals_per_sec"] = stats["proposals"] / max(run_span.dur, 1e-9)
    return SearchResult(
        params_q=adapter.install(params_base, best_fq),
        transforms=best_t,
        history=histories[elite],
        accept_rate=n_accept[elite] / max(scfg.steps, 1),
        final_loss=best[elite],
        initial_loss=loss0s[elite],
        island_histories=histories,
        stats=stats,
    )
