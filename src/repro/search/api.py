"""``repro.search.run`` — the ONE front door to the discrete search.

Unifies what used to be three entry points:

- ``core.search.run_search``            (single-phase, adapter-dispatched)
- ``core.search.run_search_hybrid``     (Zamba2 two-phase Mamba → shared FFN)
- ``search.engine.run_population_search`` (the raw engine loop)

all of which remain as thin ``DeprecationWarning`` shims. The front door
resolves the adapter from the model family, dispatches hybrid block
patterns to the two-phase composite automatically, and accepts the
objective either on the config (``SearchConfig(objective=...)``) or as the
``objective=`` keyword (a registry name or an ``Objective`` instance — the
keyword wins when both are given).

The default configuration (population=1, islands=1, temperature=0, CE
objective, replicated calibration) reproduces the paper's single-chain hill
climb bit-for-bit through this entry point — pinned by
``tests/test_search_engine.py::test_front_door_matches_legacy_bitwise``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.search.engine import _run_engine

__all__ = ["run"]


def run(
    params_fp: dict,
    params_base: dict,
    cfg,
    qcfg,
    calib_tokens,
    scfg=None,
    *,
    objective=None,
    adapter=None,
    forward_kwargs: Optional[dict] = None,
    hybrid: Optional[bool] = None,
):
    """Run the InvarExplore search; returns a ``core.search.SearchResult``.

    params_fp: original FP model (reference H₀ / KL targets / saliency).
    params_base: base-method-processed model — FFN weights in the
        continuous (dequantized) domain; every OTHER quantizable weight
        already fake-quantized (frozen during the search).
    scfg: ``core.search.SearchConfig`` (defaults reproduce the paper run).
    objective: registry name ("ce", "kl", "swd_actmatch", "saliency_ce") or
        an ``Objective`` instance; overrides ``scfg.objective``.
    adapter: explicit unit adapter; disables hybrid auto-dispatch.
    hybrid: force (True) or suppress (False) the two-phase hybrid runner;
        None auto-detects from ``cfg.block_pattern`` when no adapter is
        given (the legacy ``run_search`` shim passes False to keep its
        single-phase semantics on hybrid configs).
    """
    from repro.core.search import SearchConfig, make_adapter

    scfg = scfg if scfg is not None else SearchConfig()
    if objective is not None:
        scfg = dataclasses.replace(scfg, objective=objective)
    if hybrid is None:
        hybrid = cfg.block_pattern == "hybrid" and adapter is None
    if hybrid:
        return _run_hybrid(params_fp, params_base, cfg, qcfg, calib_tokens,
                           scfg, forward_kwargs)
    return _run_engine(params_fp, params_base, cfg, qcfg, calib_tokens,
                       scfg, adapter=adapter or make_adapter(cfg),
                       forward_kwargs=forward_kwargs)


def _run_hybrid(params_fp, params_base, cfg, qcfg, calib_tokens, scfg,
                forward_kwargs):
    """Hybrid (Zamba2) InvarExplore: phase 1 hill-climbs the Mamba blocks'
    within-head permutations; phase 2 hill-climbs the shared FFN's P/S/R,
    starting from phase 1's quantized model. Phase 2 runs the REMAINDER
    ``steps - steps // 2`` so an odd budget is spent in full, and the
    returned histories/stats merge both phases."""
    from repro.core.search import (MambaAdapter, SharedFFNAdapter,
                                   _merge_phase_stats)

    n1 = scfg.steps // 2
    n2 = scfg.steps - n1
    r1 = _run_engine(params_fp, params_base, cfg, qcfg, calib_tokens,
                     dataclasses.replace(scfg, steps=n1),
                     adapter=MambaAdapter(cfg),
                     forward_kwargs=forward_kwargs)
    r2 = _run_engine(params_fp, r1.params_q, cfg, qcfg, calib_tokens,
                     dataclasses.replace(scfg, steps=n2),
                     adapter=SharedFFNAdapter(cfg),
                     forward_kwargs=forward_kwargs)
    r2.history = r1.history + r2.history
    r2.initial_loss = r1.initial_loss
    r2.accept_rate = (r1.accept_rate * n1 + r2.accept_rate * n2) \
        / max(scfg.steps, 1)
    if r1.island_histories and r2.island_histories:
        r2.island_histories = [h1 + h2 for h1, h2 in
                               zip(r1.island_histories, r2.island_histories)]
    r2.stats = _merge_phase_stats(r1.stats, r2.stats)
    return r2
