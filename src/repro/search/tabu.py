"""Tried-point tabu/dedup memory for search proposals.

A greedy chain re-proposes from the SAME state until a move is accepted, so
the proposal distribution keeps re-drawing points the engine already paid a
full calibration forward to reject — the optuna hill-climb exemplar in
SNIPPETS.md carries exactly this ``_remove_tried_points`` structure. Here:

- a fingerprint is state-contextual: it hashes (chain digest, unit index,
  candidate transform bytes). The chain digest advances on every ACCEPTED
  move, so rejected-candidate fingerprints stay valid exactly while the
  chain state they were evaluated against is unchanged, and the whole
  memory implicitly invalidates the moment the state moves (no sweep);
- a hit replays the cached (loss, primary, aux) scalars instead of paying
  the device eval; the skip consumes NO extra PRNG — the step key was
  already spent proposing, and the accept uniform is drawn (T > 0) exactly
  as on the eval path;
- capacity-bounded LRU (``OrderedDict``), per island. A hit can never block
  an improving move: the cached scalars feed the SAME accept rule a fresh
  eval would, so only moves already seen-and-rejected at this state are
  short-circuited (pinned by tests/test_search_v2.py).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

__all__ = ["TabuMemory", "transform_bytes"]


def transform_bytes(t) -> bytes:
    """Canonical bytes of one candidate FFNTransform (host numpy views)."""
    return b"".join(np.ascontiguousarray(np.asarray(x)).tobytes()
                    for x in t)


class TabuMemory:
    """Capacity-bounded tried-point memory for ONE island's chain."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._seen: "OrderedDict[bytes, Tuple[float, float, float]]" = \
            OrderedDict()
        self._digest = b"\x00" * 16
        self.hits = 0

    def fingerprint(self, u: int, cand: bytes) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(self._digest)
        h.update(int(u).to_bytes(4, "little"))
        h.update(cand)
        return h.digest()

    def lookup(self, fp: bytes) -> Optional[Tuple[float, float, float]]:
        """Cached (loss, primary, aux) for a tried point, or None. A hit
        refreshes LRU recency and bumps the hit counter."""
        got = self._seen.get(fp)
        if got is not None:
            self._seen.move_to_end(fp)
            self.hits += 1
        return got

    def record(self, fp: bytes, loss: float, primary: float,
               aux: float) -> None:
        self._seen[fp] = (loss, primary, aux)
        self._seen.move_to_end(fp)
        while len(self._seen) > self.capacity:
            self._seen.popitem(last=False)

    def advance(self, accepted_cand: bytes) -> None:
        """Chain the digest past an accepted move: every fingerprint minted
        before this instant stops matching (stale entries age out of the
        LRU; they can never collide with post-move fingerprints)."""
        h = hashlib.blake2b(digest_size=16)
        h.update(self._digest)
        h.update(accepted_cand)
        self._digest = h.digest()

    def adopt_digest(self, other: "TabuMemory") -> None:
        """Migration rewrote this island's state to ``other``'s elite: adopt
        a digest derived from the donor's so stale local entries die."""
        h = hashlib.blake2b(digest_size=16)
        h.update(other._digest)
        h.update(b"migrate")
        self._digest = h.digest()

    def __len__(self) -> int:
        return len(self._seen)
