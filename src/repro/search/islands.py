"""Island model: independent populations + elite migration on a cadence.

Each island owns its full chain state (transforms, fake-quant stack, RNG
streams) and explores independently; every ``migrate_every`` steps the
global elite's best state replaces the worst island's current state. Island
0's streams are EXACTLY the single-chain streams (host rng
``default_rng(seed)``, device key ``PRNGKey(seed)``), so a 1-island run and
island 0 of an N-island run walk identical trajectories until a migration
actually rewrites someone's state — the reproducibility contract
``tests/test_search_engine.py`` pins.

Multi-host design (not yet wired — the engine runs islands sequentially
in-process): islands map 1:1 onto the data-parallel mesh axis, every host
running its own island on its calibration shard, with the elite exchange as
the only cross-host traffic — ``elite_over_mesh`` below is that building
block (an all-gather of one scalar loss per island via ``repro.dist``
collectives inside ``shard_map``; the winner's state then moves as one
broadcast of the unit stacks). The counter-based key discipline means no
other synchronization would be needed; hooking this into a
``jax.distributed`` run is a ROADMAP item.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import argmin_allgather

__all__ = ["IslandState", "make_island_streams", "migrate", "elite_over_mesh"]


@dataclasses.dataclass
class IslandState:
    """One chain's complete mutable search state."""

    index: int
    rng: np.random.Generator          # host stream: unit picks + accept draws
    key: jnp.ndarray                  # device stream: proposal sampling
    transforms: Any                   # stacked per-unit FFNTransform
    fq_stack: Any                     # current fake-quant unit stack
    current_loss: float
    best_loss: float                  # elite (lowest loss ever seen)
    best_transforms: Any
    best_fq: Any
    history: list = dataclasses.field(default_factory=list)
    n_accept: int = 0


def make_island_streams(seed: int, index: int):
    """(host rng, device key) for island ``index``; island 0 reproduces the
    legacy single-chain streams exactly."""
    if index == 0:
        return np.random.default_rng(seed), jax.random.PRNGKey(seed)
    return (np.random.default_rng([seed, index]),
            jax.random.fold_in(jax.random.PRNGKey(seed), index))


def migrate(islands: List[IslandState]) -> bool:
    """Elite migration: the best island's elite state overwrites the worst
    island's CURRENT state (its own elite snapshot is kept unless beaten).
    Returns True iff any state moved. Consumes no RNG from any island."""
    if len(islands) < 2:
        return False
    src = min(islands, key=lambda s: s.best_loss)
    dst = max(islands, key=lambda s: s.current_loss)
    if src is dst or src.best_loss >= dst.current_loss:
        return False
    dst.transforms = src.best_transforms
    dst.fq_stack = src.best_fq
    dst.current_loss = src.best_loss
    if src.best_loss < dst.best_loss:
        dst.best_loss = src.best_loss
        dst.best_transforms = src.best_transforms
        dst.best_fq = src.best_fq
    return True


def elite_over_mesh(loss, axis_name: str):
    """(global min loss, owning shard index) — call inside ``shard_map`` over
    the data axis to pick the migration source across hosts."""
    return argmin_allgather(jnp.asarray(loss, jnp.float32), axis_name)
