"""Island model: independent populations + elite migration on a cadence.

Each island owns its full chain state (transforms, fake-quant stack, RNG
streams) and explores independently; every ``migrate_every`` steps the
global elite's best state replaces the worst island's current state. Island
0's streams are EXACTLY the single-chain streams (host rng
``default_rng(seed)``, device key ``PRNGKey(seed)``), so a 1-island run and
island 0 of an N-island run walk identical trajectories until a migration
actually rewrites someone's state — the reproducibility contract
``tests/test_search_engine.py`` pins.

Multi-host execution (``SearchConfig(mapped=True)``, wired by
``engine._run_mapped_islands``): islands map 1:1 onto the shards of a 1-D
("data",) mesh over every global device, stepping inside ``shard_map``. The
counter-based key discipline means the only cross-shard traffic is the
migration itself: ``elite_over_mesh`` (one scalar ``argmin_allgather``) picks
the winner and ``dist.collectives.elite_broadcast`` moves its state —
``migrate_on_mesh`` below is that migration's device body, semantically
identical (including tie-breaks) to the host-side ``migrate``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import argmin_allgather, elite_broadcast

__all__ = ["IslandState", "make_island_streams", "migrate", "elite_over_mesh",
           "migrate_on_mesh", "gather_island_states", "scatter_island_states"]


@dataclasses.dataclass
class IslandState:
    """One chain's complete mutable search state."""

    index: int
    rng: np.random.Generator          # host stream: unit picks + accept draws
    key: jnp.ndarray                  # device stream: proposal sampling
    transforms: Any                   # stacked per-unit FFNTransform
    fq_stack: Any                     # current fake-quant unit stack
    current_loss: float
    best_loss: float                  # elite (lowest loss ever seen)
    best_transforms: Any
    best_fq: Any
    history: list = dataclasses.field(default_factory=list)
    n_accept: int = 0


def make_island_streams(seed: int, index: int):
    """(host rng, device key) for island ``index``; island 0 reproduces the
    legacy single-chain streams exactly."""
    if index == 0:
        return np.random.default_rng(seed), jax.random.PRNGKey(seed)
    return (np.random.default_rng([seed, index]),
            jax.random.fold_in(jax.random.PRNGKey(seed), index))


def migrate(islands: List[IslandState]) -> bool:
    """Elite migration: the best island's elite state overwrites the worst
    island's CURRENT state (its own elite snapshot is kept unless beaten).
    Returns True iff any state moved. Consumes no RNG from any island."""
    if len(islands) < 2:
        return False
    src = min(islands, key=lambda s: s.best_loss)
    dst = max(islands, key=lambda s: s.current_loss)
    if src is dst or src.best_loss >= dst.current_loss:
        return False
    dst.transforms = src.best_transforms
    dst.fq_stack = src.best_fq
    dst.current_loss = src.best_loss
    if src.best_loss < dst.best_loss:
        dst.best_loss = src.best_loss
        dst.best_transforms = src.best_transforms
        dst.best_fq = src.best_fq
    return True


def elite_over_mesh(loss, axis_name: str):
    """(global min loss, owning shard index) — call inside ``shard_map`` over
    the data axis to pick the migration source across hosts."""
    return argmin_allgather(jnp.asarray(loss, jnp.float32), axis_name)


def gather_island_states(local_states: dict, mesh, n_islands: int):
    """{island index: state tree committed to that island's device} -> one
    globally-stacked (n_islands, ...) tree laid out one-island-per-shard over
    ``mesh``'s leading axis.

    Pure data movement: each local leaf gains a length-1 leading axis on its
    own device and the global array is assembled from those buffers via
    ``jax.make_array_from_single_device_arrays`` — no host round-trip, no
    arithmetic, and under a multi-process mesh each host contributes exactly
    its addressable islands."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P(mesh.axis_names[0]))
    order = list(mesh.devices.flat)
    idx = sorted(local_states)
    trees = [local_states[i] for i in idx]

    def combine(*leaves):
        shape = (n_islands,) + leaves[0].shape
        by_dev = {order[i]: leaf[None] for i, leaf in zip(idx, leaves)}
        bufs = [by_dev[d] for d in sharding.addressable_devices_indices_map(
            shape)]
        return jax.make_array_from_single_device_arrays(shape, sharding, bufs)

    return jax.tree.map(combine, *trees)


def scatter_island_states(global_tree, local: dict):
    """Inverse of ``gather_island_states``: split a globally-stacked tree
    back into per-island trees on their shard devices ({index: device} ->
    {index: tree}). Each island's row comes straight off its addressable
    shard (``shard.data``), so this too moves no bytes across hosts."""
    def take(dev):
        def one(g):
            for s in g.addressable_shards:
                if s.device == dev:
                    return s.data[0]
            raise ValueError(f"no addressable shard on {dev}")
        return one

    return {i: jax.tree.map(take(d), global_tree) for i, d in local.items()}


def migrate_on_mesh(best_loss, cur_loss, t_stack, fq_stack, bt, bfq,
                    axis_name: str):
    """Device body of one elite migration over ``axis_name`` (shard_map
    context; every input carries a leading local island axis of size 1).

    Semantically identical to the host-side ``migrate`` — same tie-breaks
    (first minimum best as src, first maximum current as dst), same guard
    (no-op when src is dst or the elite does not beat the worst's current),
    same dst best-update rule. The scalar exchange is ONE
    ``argmin_allgather``; the winner's state moves via ``elite_broadcast``.
    Returns the four updated state trees (leading axis restored) plus a
    replicated "did anything move" flag for the engine's stats.
    """
    def strip(tree):
        return jax.tree.map(lambda x: x[0], tree)

    def lift(tree):
        return jax.tree.map(lambda x: x[None], tree)

    gmin, src = elite_over_mesh(best_loss[0], axis_name)
    cur_all = jax.lax.all_gather(cur_loss[0], axis_name)
    dst = jnp.argmax(cur_all).astype(jnp.int32)
    did = (src != dst) & (gmin < cur_all[dst])

    elite_t = elite_broadcast(strip(bt), src, axis_name)
    elite_fq = elite_broadcast(strip(bfq), src, axis_name)
    i = jax.lax.axis_index(axis_name).astype(jnp.int32)
    replace = did & (i == dst)
    improve = replace & (gmin < best_loss[0])

    new_t = jax.tree.map(lambda e, o: jnp.where(replace, e, o),
                         elite_t, strip(t_stack))
    new_fq = jax.tree.map(lambda e, o: jnp.where(replace, e, o),
                          elite_fq, strip(fq_stack))
    new_bt = jax.tree.map(lambda e, o: jnp.where(improve, e, o),
                          elite_t, strip(bt))
    new_bfq = jax.tree.map(lambda e, o: jnp.where(improve, e, o),
                           elite_fq, strip(bfq))
    return lift(new_t), lift(new_fq), lift(new_bt), lift(new_bfq), did
