"""Population proposals: K candidates per step, one batched evaluation.

The single-chain search spends one calibration forward per proposal; with a
population of K the K candidate transforms for the sampled unit are built
(unrolled at trace time — K is static) and the K fake-quant stacks are
evaluated through ONE ``vmap``-batched forward→loss program, so the
calibration batch streams through the model once per step instead of K
times.

Key discipline: ``candidate_keys(sub, 1)[0] == jax.random.split(sub)[0]``,
i.e. a population of one consumes exactly the key the legacy loop consumed
for its single proposal — this is what makes the K=1 trajectory reproduce
the legacy hill climb bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["candidate_keys", "stack_trees", "take_tree"]


def candidate_keys(sub: jnp.ndarray, k: int) -> jnp.ndarray:
    """(k, ...) proposal keys from the step key. ``k=1`` yields exactly the
    legacy ``k_prop, _ = jax.random.split(sub)`` key."""
    return jax.random.split(sub, k + 1)[:k]


def stack_trees(trees):
    """[pytree] * K -> pytree with a leading K axis on every leaf."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def take_tree(tree, i):
    """Select index ``i`` (traced ok) along the leading axis of every leaf."""
    return jax.tree.map(lambda x: x[i], tree)
