"""Temperature schedules + Metropolis acceptance for the annealed search.

The legacy hill climb accepts iff the loss strictly improves; simulated
annealing relaxes that to accepting an uphill move with probability
``exp(-Δ/T)``. Every schedule here returns ``0.0`` everywhere when the
initial temperature is ``0.0``, and ``accept(Δ, 0.0, ·)`` is exactly the
strict ``Δ < 0`` comparison — so the greedy hill-climb is the T=0 special
case of the engine, bit-for-bit (no extra RNG draws happen at T=0: the
uniform is only consumed by the T>0 branch, keeping the proposal stream
identical to the legacy loop).
"""
from __future__ import annotations

import math
from typing import Callable, Optional

__all__ = ["temperature_schedule", "accept", "SCHEDULES"]

SCHEDULES = ("constant", "geometric", "linear")


def temperature_schedule(kind: str, t0: float, steps: int,
                         t_final: float = 1e-4) -> Callable[[int], float]:
    """Return ``T(step)`` for ``step`` in [1, steps].

    - ``constant``:  T ≡ t0
    - ``geometric``: T decays from t0 to ``t_final`` on a log-linear ramp
      (the classic annealing schedule)
    - ``linear``:    T decays from t0 to 0 linearly

    ``t0 == 0`` short-circuits every schedule to the all-zeros function.
    """
    if kind not in SCHEDULES:
        raise ValueError(f"unknown anneal schedule {kind!r}; pick from {SCHEDULES}")
    if t0 <= 0.0:
        return lambda step: 0.0
    if kind == "constant":
        return lambda step: t0
    if kind == "linear":
        return lambda step: t0 * max(0.0, 1.0 - step / max(steps, 1))
    t_final = min(t_final, t0)
    ratio = t_final / t0
    return lambda step: t0 * ratio ** (min(step, steps) / max(steps, 1))


def accept(delta: float, temperature: float, uniform: Optional[float]) -> bool:
    """Metropolis rule. ``uniform`` is a pre-drawn U[0,1) sample; it may be
    None when ``temperature == 0`` (the greedy branch never reads it)."""
    if delta < 0.0:
        return True
    if temperature <= 0.0:
        return False
    return uniform < math.exp(-delta / temperature)
