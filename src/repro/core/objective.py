"""Search objectives: the paper's loss (Eqn. 23) behind a pluggable protocol.

The paper optimizes ``CE(X, quant(θ)) + α · MSE(H, H₀)`` (Algorithm 1's
listing uses an L_KL variant); the search loop itself only ever consumes a
scalar, so both live behind a first-class :class:`Objective` protocol:

- ``prepare(env) → state``      once-per-run precomputation (reference
  projections, saliency weights, …) from the frozen :class:`ObjectiveEnv`;
- ``evaluate(logits, hidden, state, env) → (primary, aux)``   the traced
  per-candidate scalar pair; the engine combines them as
  ``loss = primary + α · aux``;
- ``resolve_mix(p0, a0, env) → α``   the mixing weight from the step-0
  values (§4.1 resolves α so CE is ``ce_weight``× more important at start);
- ``metrics() → dict``          static labels for the obs registry rows.

Built-ins (see ``OBJECTIVES`` / :func:`get_objective`):

- ``"ce"``           Eqn. 23, the default — bit-for-bit the legacy loss;
- ``"kl"``           the Algorithm-1 listing's label-free KL variant;
- ``"swd_actmatch"`` sliced-Wasserstein alignment of tapped activations
  (random-projection 1-D Wasserstein, PAPERS.md: Cao/Yin/Aref 2026);
- ``"saliency_ce"``  per-token CE weighted by the FP model's confidence in
  the true token (PAPERS.md: Cao/Aref 2025).

``SearchConfig.objective`` accepts a registry name or an ``Objective``
instance; the loose functions (``calib_ce`` …) remain exported for direct
use and for the legacy-parity test's verbatim transcription.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Union

import jax
import jax.numpy as jnp

from repro.models.model import lm_loss

__all__ = ["calib_ce", "calib_kl", "activation_mse", "resolve_alpha",
           "ObjectiveEnv", "Objective", "CEObjective", "KLObjective",
           "SWDActMatchObjective", "SaliencyCEObjective", "OBJECTIVES",
           "register_objective", "get_objective", "objective_name"]


def calib_ce(logits, tokens, vocab_size: int):
    """Next-token cross-entropy on the calibration batch."""
    return lm_loss(logits[:, :-1], tokens[:, 1:], vocab_size)


def calib_kl(logits_q, logits_fp, vocab_size: int):
    """KL(p_fp || p_q) averaged over positions."""
    V = logits_q.shape[-1]
    if V > vocab_size:
        mask = jnp.arange(V) < vocab_size
        neg = jnp.finfo(jnp.float32).min / 2
        logits_q = jnp.where(mask, logits_q, neg)
        logits_fp = jnp.where(mask, logits_fp, neg)
    lq = jax.nn.log_softmax(logits_q.astype(jnp.float32), axis=-1)
    lp = jax.nn.log_softmax(logits_fp.astype(jnp.float32), axis=-1)
    p = jnp.exp(lp)
    return jnp.mean(jnp.sum(p * (lp - lq), axis=-1))


def activation_mse(hidden_q, hidden_fp, n_match: int):
    """MSE over the first ``n_match`` per-layer block outputs.

    hidden_*: (L, B, S, D) stacks from forward(collect_hidden=True).
    n_match == 0 disables activation matching (paper Table 4, '0 layers').
    """
    if n_match == 0:
        return jnp.float32(0.0)
    hq = hidden_q[:n_match].astype(jnp.float32)
    hf = hidden_fp[:n_match].astype(jnp.float32)
    return jnp.mean(jnp.square(hq - hf))


def resolve_alpha(ce0: float, mse0: float, ce_weight: float = 10.0) -> float:
    """Paper §4.1: α chosen so CE is ``ce_weight``× more important than the
    activation MSE at the start of the search."""
    if mse0 <= 0:
        return 0.0
    return float(ce0 / (ce_weight * mse0))


# ---------------------------------------------------------------------------
# The Objective protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ObjectiveEnv:
    """Everything an objective may read, fixed for one engine run (one island
    under sharded calibration): the calibration slice, the FP reference
    forward on that slice, and the paper's matching hyper-parameters."""

    calib: Any                    # (B, S) int tokens
    logits_fp: Any                # (B, S, V) FP reference logits
    hidden_fp: Any                # (n_match, B, S, D) FP taps, or None
    vocab_size: int
    n_match: int
    ce_weight: float = 10.0


class Objective:
    """Base protocol; subclasses override the four hooks below.

    ``evaluate`` runs inside the engine's jitted candidate-eval program, so
    it must be pure and shape-stable; ``prepare`` runs once on the host and
    may allocate reference state (device arrays welcome — they are closed
    over by the jitted program).
    """

    name = "objective"

    def prepare(self, env: ObjectiveEnv) -> Any:
        return None

    def evaluate(self, logits, hidden, state, env: ObjectiveEnv):
        raise NotImplementedError

    def resolve_mix(self, primary0: float, aux0: float,
                    env: ObjectiveEnv) -> float:
        return 0.0

    def metrics(self) -> Dict[str, str]:
        return {"objective": self.name}


class CEObjective(Objective):
    """Eqn. 23: calibration CE + α · activation MSE — the paper default.

    The traced graph is primitive-for-primitive the legacy engine's, which
    is what keeps the pop=1/isl=1/T=0 trajectory bit-for-bit."""

    name = "ce"

    def evaluate(self, logits, hidden, state, env: ObjectiveEnv):
        primary = calib_ce(logits, env.calib, env.vocab_size)
        aux = (activation_mse(hidden, env.hidden_fp, env.n_match)
               if env.n_match else jnp.float32(0.0))
        return primary, aux

    def resolve_mix(self, primary0, aux0, env):
        return resolve_alpha(primary0, aux0, env.ce_weight) \
            if env.n_match else 0.0


class KLObjective(CEObjective):
    """Algorithm-1 listing: KL(p_fp || p_q) + α · activation MSE."""

    name = "kl"

    def evaluate(self, logits, hidden, state, env: ObjectiveEnv):
        primary = calib_kl(logits, env.logits_fp, env.vocab_size)
        aux = (activation_mse(hidden, env.hidden_fp, env.n_match)
               if env.n_match else jnp.float32(0.0))
        return primary, aux


def _swd_1d(x_sorted, y):
    """1-D Wasserstein-2² between pre-sorted reference projections and a new
    sample set: sort y, mean squared quantile difference."""
    return jnp.mean(jnp.square(jnp.sort(y, axis=0) - x_sorted))


class SWDActMatchObjective(Objective):
    """Sliced-Wasserstein activation alignment (PAPERS.md 2601.07878).

    Project the tapped activations of the quantized and FP models onto
    ``n_proj`` fixed random directions, sort each 1-D cloud, and average the
    squared quantile differences — a distributional match that, unlike the
    pointwise MSE, tolerates token-position reshuffling while still pinning
    the activation geometry. With ``n_match == 0`` the logits cloud is
    matched instead (data-free variant). ``aux`` is the calibration CE so
    ``resolve_mix`` can anchor the scale the same way the paper anchors α.
    """

    name = "swd_actmatch"

    def __init__(self, n_proj: int = 64, proj_seed: int = 0,
                 ce_anchor: bool = True):
        self.n_proj = int(n_proj)
        self.proj_seed = int(proj_seed)
        self.ce_anchor = bool(ce_anchor)

    def _features(self, hidden, env: ObjectiveEnv):
        if env.n_match and hidden is not None:
            h = hidden[:env.n_match].astype(jnp.float32)
            return h.reshape(env.n_match, -1, h.shape[-1])    # (L, N, D)
        return None

    def prepare(self, env: ObjectiveEnv):
        key = jax.random.PRNGKey(self.proj_seed)
        feats = self._features(env.hidden_fp, env)
        if feats is None:   # data-free fallback: match the logits cloud
            ref = env.logits_fp.astype(jnp.float32)
            ref = ref.reshape(1, -1, ref.shape[-1])
            feats = ref
        d = feats.shape[-1]
        dirs = jax.random.normal(key, (d, self.n_proj), jnp.float32)
        dirs = dirs / (jnp.linalg.norm(dirs, axis=0, keepdims=True) + 1e-12)
        # (L, N, n_proj) reference projections, pre-sorted along samples
        ref_sorted = jnp.sort(feats @ dirs, axis=1)
        return {"dirs": jax.lax.stop_gradient(dirs),
                "ref_sorted": jax.lax.stop_gradient(ref_sorted)}

    def evaluate(self, logits, hidden, state, env: ObjectiveEnv):
        feats = self._features(hidden, env)
        if feats is None:
            lg = logits.astype(jnp.float32)
            feats = lg.reshape(1, -1, lg.shape[-1])
        proj = feats @ state["dirs"]                          # (L, N, n_proj)
        swd = jax.vmap(_swd_1d)(state["ref_sorted"], proj).mean()
        aux = (calib_ce(logits, env.calib, env.vocab_size)
               if self.ce_anchor else jnp.float32(0.0))
        return swd, aux

    def resolve_mix(self, primary0, aux0, env):
        # anchor: the CE term starts 1/ce_weight as important as the SWD
        if not self.ce_anchor or aux0 <= 0:
            return 0.0
        return float(primary0 / (env.ce_weight * aux0))

    def metrics(self):
        return {"objective": self.name, "n_proj": str(self.n_proj)}


class SaliencyCEObjective(Objective):
    """Saliency-weighted CE (PAPERS.md 2504.13932): per-token NLL weighted by
    the FP model's probability of the true token, so tokens the full-precision
    model is confident about dominate the search signal while tokens it
    already gets wrong cannot drag the climb. Weights are normalized to mean
    1 over valid positions (the unweighted CE is the all-ones special case);
    ``aux`` is the paper's activation MSE, mixed exactly like ``"ce"``."""

    name = "saliency_ce"

    def __init__(self, temperature: float = 1.0):
        self.temperature = float(temperature)

    def prepare(self, env: ObjectiveEnv):
        lp = jax.nn.log_softmax(
            env.logits_fp[:, :-1].astype(jnp.float32) / self.temperature,
            axis=-1)
        labels = env.calib[:, 1:]
        p_true = jnp.take_along_axis(
            jnp.exp(lp), labels[..., None], axis=-1)[..., 0]
        w = p_true / jnp.maximum(jnp.mean(p_true), 1e-9)
        return {"w": jax.lax.stop_gradient(w)}

    def evaluate(self, logits, hidden, state, env: ObjectiveEnv):
        lg = logits[:, :-1]
        labels = env.calib[:, 1:]
        V = lg.shape[-1]
        if V > env.vocab_size:
            mask = jnp.arange(V) < env.vocab_size
            neg = jnp.finfo(jnp.float32).min / 2
            lg = jnp.where(mask[None, None, :], lg, neg)
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        primary = jnp.mean(nll * state["w"])
        aux = (activation_mse(hidden, env.hidden_fp, env.n_match)
               if env.n_match else jnp.float32(0.0))
        return primary, aux

    def resolve_mix(self, primary0, aux0, env):
        return resolve_alpha(primary0, aux0, env.ce_weight) \
            if env.n_match else 0.0

    def metrics(self):
        return {"objective": self.name,
                "saliency_temperature": str(self.temperature)}


# ---------------------------------------------------------------------------
# Registry: string names <-> Objective instances
# ---------------------------------------------------------------------------

OBJECTIVES: Dict[str, Callable[[], Objective]] = {
    "ce": CEObjective,
    "kl": KLObjective,
    "swd_actmatch": SWDActMatchObjective,
    "saliency_ce": SaliencyCEObjective,
}


def register_objective(name: str, factory: Callable[[], Objective],
                       overwrite: bool = False) -> None:
    """Register a custom objective factory under ``name`` (what
    ``SearchConfig.objective`` strings resolve through)."""
    if name in OBJECTIVES and not overwrite:
        raise ValueError(f"objective {name!r} already registered")
    OBJECTIVES[name] = factory


def get_objective(spec: Union[str, Objective, None]) -> Objective:
    """Resolve ``SearchConfig.objective``: a registry name, an ``Objective``
    instance (returned as-is), or None (the default CE objective)."""
    if spec is None:
        return CEObjective()
    if isinstance(spec, Objective):
        return spec
    if isinstance(spec, str):
        try:
            return OBJECTIVES[spec]()
        except KeyError:
            raise ValueError(
                f"unknown objective {spec!r}; registered: "
                f"{sorted(OBJECTIVES)}") from None
    raise TypeError(
        f"objective must be a name or an Objective, got {type(spec).__name__}")


def objective_name(spec: Union[str, Objective, None]) -> str:
    """The stats/metrics label for an objective spec without instantiating
    twice (names are stable identity for registry round-trips)."""
    if spec is None:
        return "ce"
    if isinstance(spec, Objective):
        return spec.name
    return str(spec)
