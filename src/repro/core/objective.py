"""Search objective (paper Eqn. 23): CE(X, quant(θ)) + α · MSE(H, H₀).

Algorithm 1's listing uses an L_KL variant; both are provided
(``objective="ce"`` follows Eqn. 23 and is the default; ``"kl"`` matches the
algorithm listing — KL between the FP16 model's token distribution and the
quantized model's, which needs no labels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import lm_loss

__all__ = ["calib_ce", "calib_kl", "activation_mse", "resolve_alpha"]


def calib_ce(logits, tokens, vocab_size: int):
    """Next-token cross-entropy on the calibration batch."""
    return lm_loss(logits[:, :-1], tokens[:, 1:], vocab_size)


def calib_kl(logits_q, logits_fp, vocab_size: int):
    """KL(p_fp || p_q) averaged over positions."""
    V = logits_q.shape[-1]
    if V > vocab_size:
        mask = jnp.arange(V) < vocab_size
        neg = jnp.finfo(jnp.float32).min / 2
        logits_q = jnp.where(mask, logits_q, neg)
        logits_fp = jnp.where(mask, logits_fp, neg)
    lq = jax.nn.log_softmax(logits_q.astype(jnp.float32), axis=-1)
    lp = jax.nn.log_softmax(logits_fp.astype(jnp.float32), axis=-1)
    p = jnp.exp(lp)
    return jnp.mean(jnp.sum(p * (lp - lq), axis=-1))


def activation_mse(hidden_q, hidden_fp, n_match: int):
    """MSE over the first ``n_match`` per-layer block outputs.

    hidden_*: (L, B, S, D) stacks from forward(collect_hidden=True).
    n_match == 0 disables activation matching (paper Table 4, '0 layers').
    """
    if n_match == 0:
        return jnp.float32(0.0)
    hq = hidden_q[:n_match].astype(jnp.float32)
    hf = hidden_fp[:n_match].astype(jnp.float32)
    return jnp.mean(jnp.square(hq - hf))


def resolve_alpha(ce0: float, mse0: float, ce_weight: float = 10.0) -> float:
    """Paper §4.1: α chosen so CE is ``ce_weight``× more important than the
    activation MSE at the start of the search."""
    if mse0 <= 0:
        return 0.0
    return float(ce0 / (ce_weight * mse0))
