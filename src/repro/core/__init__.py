"""InvarExplore core: quantization, invariant transforms, discrete search,
and the RTN/GPTQ/AWQ/OmniQuant baselines it composes with.

search/pipeline are imported lazily (they depend on repro.models, which
depends on repro.core.quant — a direct import here would be circular).
"""
from repro.core.quant import QuantConfig, QTensor, fake_quant, quantize_tensor, bits_per_param
from repro.core.invariance import (
    FFNTransform, identity_transform, apply_transform_ffn, propose, ProposalConfig,
)

__all__ = [
    "QuantConfig", "QTensor", "fake_quant", "quantize_tensor", "bits_per_param",
    "FFNTransform", "identity_transform", "apply_transform_ffn", "propose",
    "ProposalConfig", "SearchConfig", "SearchResult", "run_search", "make_adapter",
    "quantize_model", "PTQResult",
]

_LAZY = {
    "SearchConfig": "repro.core.search",
    "SearchResult": "repro.core.search",
    "run_search": "repro.core.search",
    "make_adapter": "repro.core.search",
    "quantize_model": "repro.core.pipeline",
    "PTQResult": "repro.core.pipeline",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name])
        return getattr(mod, name)
    raise AttributeError(name)
