"""AWQ baseline (Lin et al. 2024b) in JAX: activation-aware scaling + clipping.

Scaling — the paper's framing: AWQ's per-channel scaling is the SPECIAL CASE
of InvarExplore's S transform on the FFN hidden axis, with s chosen by a grid
search over ``s = act_mag^α`` (α ∈ [0, 1], 20 points) minimizing the quantized
block-output MSE. (Exact invariance for ReLU; AWQ applies it regardless.)

Clipping — per-group max/min shrink grid-searched to minimize per-matrix
output MSE (AWQ's second component; also used by OmniQuant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, fake_quant, _grouped
from repro.core.taps import capture_dense_taps
from repro.models.config import ModelConfig
from repro.models import layers as L

__all__ = ["awq_scale_ffn", "clip_search", "awq_process_dense"]


def _fq(w, qcfg):
    return fake_quant(w, qcfg)


def awq_scale_ffn(w_up, w_down, b_up, w_gate, x_mlp, qcfg: QuantConfig,
                  cfg: ModelConfig, n_grid: int = 20):
    """Grid-search the hidden-axis scaling vector for one FFN.

    x_mlp: (n, D) inputs of the up projection. Returns scaled
    (w_up, w_down, b_up, w_gate) and the chosen s (F,).
    """
    act = L.activation_fn(cfg.activation)

    def ffn(wu, wd, bu, wg, x):
        up = x @ wu
        if bu is not None:
            up = up + bu
        if wg is not None:
            h = act(x @ wg) * up
        else:
            h = act(up)
        return h @ wd

    y_fp = ffn(w_up, w_down, b_up, w_gate, x_mlp)
    # activation magnitude per hidden channel (input of down projection)
    up = x_mlp @ w_up + (b_up if b_up is not None else 0.0)
    mid = act(x_mlp @ w_gate) * up if w_gate is not None else act(up)
    act_mag = jnp.mean(jnp.abs(mid), axis=0) + 1e-8          # (F,)

    def try_alpha(alpha):
        s = jnp.power(act_mag, alpha)
        s = s / jnp.sqrt(jnp.max(s) * jnp.min(s) + 1e-12)
        s = jnp.clip(s, 1e-4, 1e4)
        wu = _fq(w_up * s[None, :], qcfg)
        wd = _fq(w_down / s[:, None], qcfg)
        bu = b_up * s if b_up is not None else None
        wg = _fq(w_gate, qcfg) if w_gate is not None else None
        y = ffn(wu, wd, bu, wg, x_mlp)
        return jnp.mean(jnp.square(y - y_fp)), s

    alphas = jnp.linspace(0.0, 1.0, n_grid)
    losses, scales = jax.lax.map(try_alpha, alphas)
    best = jnp.argmin(losses)
    s = scales[best]
    out_up = w_up * s[None, :]
    out_down = w_down / s[:, None]
    out_b = b_up * s if b_up is not None else None
    return out_up, out_down, out_b, w_gate, s


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "n_grid"))
def clip_search(w, x, bits: int, group_size: int, n_grid: int = 10):
    """Per-group clip-ratio grid search minimizing ||x@w - x@fq(clip(w))||².

    Returns the clipped (still continuous-domain) weights.
    """
    qcfg = QuantConfig(bits=bits, group_size=group_size)
    y_fp = x @ w

    def try_ratio(r):
        g = qcfg.resolve_group(w.shape[0])
        wg = _grouped(w, g)
        wmax = jnp.max(wg, axis=1, keepdims=True) * r
        wmin = jnp.min(wg, axis=1, keepdims=True) * r
        wc = jnp.clip(wg, wmin, wmax).reshape(w.shape)
        y = x @ fake_quant(wc, qcfg)
        return jnp.mean(jnp.square(y - y_fp)), wc

    ratios = jnp.linspace(0.5, 1.0, n_grid)
    losses, cands = jax.lax.map(try_ratio, ratios)
    return cands[jnp.argmin(losses)]


def awq_process_dense(params, cfg: ModelConfig, calib_tokens, qcfg: QuantConfig,
                      do_clip: bool = True):
    """AWQ over a dense decoder: hidden-axis scaling per FFN + weight clipping
    on every quantizable linear. Returns continuous-domain processed params."""
    taps = capture_dense_taps(params, cfg, calib_tokens)
    x_mlp = taps["mlp_in"].reshape(taps["mlp_in"].shape[0], -1, cfg.d_model)
    x_attn = taps["attn_in"].reshape(taps["attn_in"].shape[0], -1, cfg.d_model)
    x_wo = taps["attn_mid"].reshape(taps["attn_mid"].shape[0], -1,
                                    taps["attn_mid"].shape[-1])

    blocks = dict(params["blocks"])
    mlp = dict(blocks["mlp"])
    has_bias = "b_up" in mlp
    has_gate = "gate" in mlp
    wu, wd, bu, wg = _scale_dispatch(mlp, x_mlp, qcfg, cfg)
    mlp["up"], mlp["down"] = wu, wd
    if has_bias:
        mlp["b_up"] = bu
    if has_gate:
        mlp["gate"] = wg

    if do_clip:
        def clip(w, x):
            return jax.vmap(lambda wi, xi: clip_search(
                wi, xi, qcfg.bits, qcfg.group_size))(w, x)
        x_mid = taps["mlp_mid"].reshape(taps["mlp_mid"].shape[0], -1, cfg.d_ff)
        mlp["up"] = clip(mlp["up"], x_mlp)
        if has_gate:
            mlp["gate"] = clip(mlp["gate"], x_mlp)
        mlp["down"] = clip(mlp["down"], x_mid)
        attn = dict(blocks["attn"])
        for k, x in (("wq", x_attn), ("wk", x_attn), ("wv", x_attn), ("wo", x_wo)):
            attn[k] = clip(attn[k], x)
        blocks["attn"] = attn
    blocks["mlp"] = mlp
    out = dict(params)
    out["blocks"] = blocks
    return out


def _scale_dispatch(mlp, x_mlp, qcfg, cfg):
    """vmap wrapper handling optional bias/gate without tracing Nones."""
    has_bias = "b_up" in mlp
    has_gate = "gate" in mlp

    def one(u, d, b, g, x):
        bu = b if has_bias else None
        wg = g if has_gate else None
        ou, od, ob, og, _ = awq_scale_ffn(u, d, bu, wg, x, qcfg, cfg)
        return (ou, od,
                ob if ob is not None else jnp.zeros(u.shape[1], u.dtype),
                og if og is not None else jnp.zeros_like(u))

    L_ = mlp["up"].shape[0]
    dummy_b = mlp.get("b_up", jnp.zeros((L_, mlp["up"].shape[2]), mlp["up"].dtype))
    dummy_g = mlp.get("gate", jnp.zeros_like(mlp["up"]))
    return jax.vmap(one)(mlp["up"], mlp["down"], dummy_b, dummy_g, x_mlp)
