"""Per-linear input capture ("taps") for calibration-based PTQ (GPTQ/AWQ).

Re-runs the dense decoder block math with the same ``repro.models.layers``
primitives, emitting the input activations of every quantizable linear:

    attn_in (L,B,S,D)   — input of wq/wk/wv
    attn_mid (L,B,S,HqDh) — input of wo
    mlp_in (L,B,S,D)    — input of up/gate
    mlp_mid (L,B,S,F)   — input of down

Dense pattern only (the paper's OPT family); other families use RTN/AWQ-lite
paths documented in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.model import embed_tokens

__all__ = ["capture_dense_taps"]


def capture_dense_taps(params, cfg: ModelConfig, tokens):
    assert cfg.block_pattern == "dense" and not cfg.is_enc_dec
    B, S = tokens.shape
    h = embed_tokens(params, cfg, tokens, jnp.arange(S))
    positions = jnp.arange(S)

    def body(carry, pl):
        h = carry
        a_in = L.apply_norm(h, pl["ln1"], cfg.norm)
        q, k, v = L.attn_qkv(pl["attn"], cfg, a_in, positions)
        attn = L.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                                   unroll=cfg.unroll_inner)
        attn_mid = attn.reshape(B, S, -1)
        a = attn_mid @ pl["attn"]["wo"]
        if "bo" in pl["attn"]:
            a = a + pl["attn"]["bo"]
        h = h + a
        m_in = L.apply_norm(h, pl["ln2"], cfg.norm)
        act = L.activation_fn(cfg.activation)
        up = m_in @ pl["mlp"]["up"]
        if "b_up" in pl["mlp"]:
            up = up + pl["mlp"]["b_up"]
        if cfg.gated_mlp:
            g = m_in @ pl["mlp"]["gate"]
            if "b_gate" in pl["mlp"]:
                g = g + pl["mlp"]["b_gate"]
            mid = act(g) * up
        else:
            mid = act(up)
        out = mid @ pl["mlp"]["down"]
        if "b_down" in pl["mlp"]:
            out = out + pl["mlp"]["b_down"]
        h = h + out
        taps = {"attn_in": a_in, "attn_mid": attn_mid, "mlp_in": m_in, "mlp_mid": mid}
        return h, taps

    _, taps = jax.lax.scan(body, h, params["blocks"])
    return taps
