"""End-to-end PTQ pipeline: base method → (optional) InvarExplore search.

    params_q = quantize_model(params_fp, cfg, qcfg, method="awq",
                              calib_tokens=X, search=SearchConfig(...))

Contract between stages (DESIGN.md §1):
  * the base method produces FFN weights in the continuous (dequantized)
    domain — AWQ-scaled/clipped, GPTQ-compensated, OmniQuant-optimized, or
    plain θ₀ for RTN — and FINAL fake-quant weights for everything else
    (attention projections), which stay frozen during the search;
  * InvarExplore then hill-climbs fq(T(θ_base)) per unit (Algorithm 1);
  * without the search, the FFN weights are simply fake-quantized.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


from repro.core.quant import QuantConfig, fake_quant
from repro.core.rtn import map_quantizable
from repro.core.awq import awq_process_dense
from repro.core.gptq import gptq_process_dense
from repro.core.omniquant import omniquant_process_dense
from repro.core.search import SearchConfig
from repro.search.api import run as run_invar_search
from repro.models.config import ModelConfig

__all__ = ["quantize_model", "PTQResult"]

# leaves the search transforms (kept continuous until the search quantizes
# them): dense/MoE FFNs plus the Mamba projections (within-head permutation
# targets — DESIGN.md §Arch-applicability)
_FFN_KEYS = ("up", "gate", "down", "w_z", "w_x", "out_proj")


def _is_ffn(path):
    return path[-1] in _FFN_KEYS


@dataclasses.dataclass
class PTQResult:
    params_q: dict
    method: str
    search: Optional[object]  # SearchResult when InvarExplore ran


def quantize_model(
    params_fp: dict,
    cfg: ModelConfig,
    qcfg: QuantConfig,
    method: str = "rtn",
    calib_tokens=None,
    search: Optional[SearchConfig] = None,
    forward_kwargs: Optional[dict] = None,
) -> PTQResult:
    if method != "rtn" and calib_tokens is None:
        raise ValueError(f"method {method!r} needs calib_tokens")

    # 1) base-method processing (continuous-domain FFN weights)
    if method == "rtn":
        params_base = params_fp
    elif method == "awq":
        params_base = awq_process_dense(params_fp, cfg, calib_tokens, qcfg)
    elif method == "gptq":
        params_base = gptq_process_dense(params_fp, cfg, calib_tokens, qcfg)
    elif method == "omniquant":
        params_base, _ = omniquant_process_dense(params_fp, cfg, calib_tokens, qcfg)
    else:
        raise ValueError(f"unknown method {method!r}")

    # 2) freeze non-FFN quantizable weights at their fake-quant values
    params_base = map_quantizable(
        params_base, lambda w, p: fake_quant(w, qcfg), only=lambda p: not _is_ffn(p))

    # 3) InvarExplore search or plain FFN fake-quant
    if search is not None:
        result = run_invar_search(params_fp, params_base, cfg, qcfg,
                                  calib_tokens, search,
                                  forward_kwargs=forward_kwargs)
        return PTQResult(result.params_q, method + "+invarexplore", result)

    params_q = map_quantizable(
        params_base, lambda w, p: fake_quant(w, qcfg), only=_is_ffn)
    return PTQResult(params_q, method, None)
