"""OmniQuant-lite baseline (Shao et al. 2024): learnable weight clipping (LWC)
+ learnable equivalent scaling (LET), trained with a straight-through
estimator on block-wise output MSE.

Per FFN block, the learnables are:
  gamma/beta: per-group sigmoid-parameterized shrink of (max, min) for up/down
  log_s:      hidden-axis equivalent scaling (the gradient-based counterpart
              of the paper's discrete S search)
optimized with Adam for ``steps`` iterations. This is a faithful but reduced
re-implementation (block-wise error minimization, STE through round()).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, _grouped
from repro.core.taps import capture_dense_taps
from repro.models.config import ModelConfig
from repro.models import layers as L

__all__ = ["fake_quant_lwc", "omniquant_process_dense"]


def fake_quant_lwc(w, qcfg: QuantConfig, gamma, beta):
    """Fake-quant with learnable clipping; differentiable via STE.

    gamma/beta: (K//G, N) logits; sigmoid(·) shrinks max/min.
    """
    g = qcfg.resolve_group(w.shape[0])
    wg = _grouped(w.astype(jnp.float32), g)
    wmax = jnp.max(wg, axis=1) * jax.nn.sigmoid(gamma)
    wmin = jnp.min(wg, axis=1) * jax.nn.sigmoid(beta)
    scale = jnp.maximum((wmax - wmin) / (qcfg.q_max - qcfg.q_min), 1e-8)
    zero = jnp.round(qcfg.q_min - wmin / scale)
    q = wg / scale[:, None] + zero[:, None]
    q_ste = q + jax.lax.stop_gradient(jnp.clip(jnp.round(q), qcfg.q_min, qcfg.q_max) - q)
    dq = (q_ste - zero[:, None]) * scale[:, None]
    return dq.reshape(w.shape).astype(w.dtype)


def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    return -lr * mhat / (jnp.sqrt(vhat) + eps), m, v


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "steps", "gated", "act_name"))
def _optimize_block(w_up, w_down, w_gate, b_up, x, bits, group_size, steps,
                    gated, act_name, lr=5e-3):
    qcfg = QuantConfig(bits=bits, group_size=group_size)
    act = L.activation_fn(act_name)
    F = w_up.shape[1]
    gsz = qcfg.resolve_group(w_up.shape[0])
    gsz_d = qcfg.resolve_group(w_down.shape[0])

    def ffn(wu, wd, wg, bu, x):
        up = x @ wu + bu
        h = act(x @ wg) * up if gated else act(up)
        return h @ wd

    y_fp = ffn(w_up, w_down, w_gate, b_up, x)

    theta = {
        "g_up": jnp.full((w_up.shape[0] // gsz, F), 4.0),
        "b_up_c": jnp.full((w_up.shape[0] // gsz, F), 4.0),
        "g_dn": jnp.full((F // gsz_d, w_down.shape[1]), 4.0),
        "b_dn_c": jnp.full((F // gsz_d, w_down.shape[1]), 4.0),
        "log_s": jnp.zeros((F,)),
    }

    def loss_fn(theta):
        s = jnp.exp(theta["log_s"])
        wu = fake_quant_lwc(w_up * s[None, :], qcfg, theta["g_up"], theta["b_up_c"])
        wd = fake_quant_lwc(w_down / s[:, None], qcfg, theta["g_dn"], theta["b_dn_c"])
        y = ffn(wu, wd, w_gate, b_up * s, x)
        return jnp.mean(jnp.square(y - y_fp))

    def step(carry, t):
        theta, m, v = carry
        loss, g = jax.value_and_grad(loss_fn)(theta)
        def upd(p, gi, mi, vi):
            d, mi, vi = _adam_update(gi, mi, vi, t + 1.0, lr)
            return p + d, mi, vi
        new = jax.tree.map(upd, theta, g, m, v)
        def is_triple(x):
            return isinstance(x, tuple)
        theta = jax.tree.map(lambda x: x[0], new, is_leaf=is_triple)
        m = jax.tree.map(lambda x: x[1], new, is_leaf=is_triple)
        v = jax.tree.map(lambda x: x[2], new, is_leaf=is_triple)
        return (theta, m, v), loss

    zeros = jax.tree.map(jnp.zeros_like, theta)
    (theta, _, _), losses = jax.lax.scan(
        step, (theta, zeros, zeros), jnp.arange(steps, dtype=jnp.float32))

    s = jnp.exp(theta["log_s"])
    wu = fake_quant_lwc(w_up * s[None, :], qcfg, theta["g_up"], theta["b_up_c"])
    wd = fake_quant_lwc(w_down / s[:, None], qcfg, theta["g_dn"], theta["b_dn_c"])
    return wu, wd, b_up * s, losses


def omniquant_process_dense(params, cfg: ModelConfig, calib_tokens,
                            qcfg: QuantConfig, steps: int = 200):
    """Block-wise LWC+LET optimization of every FFN. Returns params whose FFN
    weights are the OPTIMIZED fake-quant weights (already on the grid)."""
    taps = capture_dense_taps(params, cfg, calib_tokens)
    x_mlp = taps["mlp_in"].reshape(taps["mlp_in"].shape[0], -1, cfg.d_model)

    blocks = dict(params["blocks"])
    mlp = dict(blocks["mlp"])
    gated = "gate" in mlp
    L_ = mlp["up"].shape[0]
    b_up = mlp.get("b_up", jnp.zeros((L_, cfg.d_ff), mlp["up"].dtype))
    gate = mlp.get("gate", jnp.zeros_like(mlp["up"]))

    run = jax.vmap(lambda wu, wd, wg, bu, x: _optimize_block(
        wu, wd, wg, bu, x, qcfg.bits, qcfg.group_size, steps, gated, cfg.activation))
    wu, wd, bu, losses = run(mlp["up"], mlp["down"], gate, b_up, x_mlp)
    mlp["up"], mlp["down"] = wu, wd
    if "b_up" in mlp:
        mlp["b_up"] = bu
    blocks["mlp"] = mlp
    out = dict(params)
    out["blocks"] = blocks
    return out, losses
