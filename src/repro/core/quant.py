"""Asymmetric integer group quantization (paper §3.1).

Weights are quantized in groups of ``group_size`` *contiguous* values along the
input (K) axis of a ``(K, N)`` weight used as ``x @ W``:

    quant(W_g)   = clip(round(W_g / s_g) + z_g, q_min, q_max)        (Eqn. 1)
    s_g          = (max(W_g) - min(W_g)) / (q_max - q_min)           (Eqn. 2)
    z_g          = round(q_min - min(W_g) / s_g)                     (Eqn. 3)
    dequant(q_g) = s_g * (q_g - z_g)                                 (Eqn. 4)

``fake_quant`` is the quant→dequant roundtrip used by the discrete search;
``QTensor`` is the packed storage format used by the serving path (codes are
bit-packed into uint32 words along K).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantConfig",
    "QTensor",
    "compute_qparams",
    "quantize_codes",
    "dequantize_codes",
    "fake_quant",
    "pack_codes",
    "unpack_codes",
    "quantize_tensor",
    "bits_per_param",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization configuration."""

    bits: int = 2
    group_size: int = 128  # groups along axis 0 (K); -1 => per-column (one group)
    scale_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if self.bits < 1 or self.bits > 8:
            raise ValueError(f"bits must be in [1, 8], got {self.bits}")

    @property
    def q_min(self) -> int:
        return 0

    @property
    def q_max(self) -> int:
        return (1 << self.bits) - 1

    def resolve_group(self, k: int) -> int:
        g = k if self.group_size in (-1, None) else self.group_size
        if k % g != 0:
            raise ValueError(f"K={k} not divisible by group_size={g}")
        return g


def _grouped(w: jnp.ndarray, group: int) -> jnp.ndarray:
    """(K, ...) -> (K//G, G, ...)."""
    k = w.shape[0]
    return w.reshape((k // group, group) + w.shape[1:])


def compute_qparams(w: jnp.ndarray, cfg: QuantConfig):
    """Closed-form scale / zero-point per group (Eqns. 2-3).

    w: (K, N) or (K,). Returns (scale, zero), each (K//G, N) / (K//G,).
    """
    g = cfg.resolve_group(w.shape[0])
    wg = _grouped(w, g)
    wmax = jnp.max(wg, axis=1)
    wmin = jnp.min(wg, axis=1)
    scale = (wmax - wmin) / (cfg.q_max - cfg.q_min)
    scale = jnp.maximum(scale, 1e-8).astype(cfg.scale_dtype)
    zero = jnp.round(cfg.q_min - wmin / scale)
    zero = jnp.clip(zero, cfg.q_min, cfg.q_max)
    return scale, zero


def quantize_codes(w: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                   cfg: QuantConfig) -> jnp.ndarray:
    """Eqn. 1 with clipping to the representable range. Returns int32 codes."""
    g = cfg.resolve_group(w.shape[0])
    wg = _grouped(w, g)
    q = jnp.round(wg / scale[:, None].astype(jnp.float32)) + zero[:, None]
    q = jnp.clip(q, cfg.q_min, cfg.q_max)
    return q.reshape(w.shape).astype(jnp.int32)


def dequantize_codes(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                     cfg: QuantConfig, out_dtype=jnp.float32) -> jnp.ndarray:
    """Eqn. 4."""
    g = cfg.resolve_group(codes.shape[0])
    qg = _grouped(codes.astype(jnp.float32), g)
    w = (qg - zero[:, None]) * scale[:, None].astype(jnp.float32)
    return w.reshape(codes.shape).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def _fake_quant_impl(w, bits: int, group_size: int):
    cfg = QuantConfig(bits=bits, group_size=group_size)
    scale, zero = compute_qparams(w, cfg)
    codes = quantize_codes(w, scale, zero, cfg)
    return dequantize_codes(codes, scale, zero, cfg, out_dtype=w.dtype)


def fake_quant(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """quant -> dequant roundtrip (the search's inner primitive).

    Accepts (K, N), (K,) or stacked (L, K, N) / (E, K, N) inputs — grouping is
    always along axis -2 for matrices (the K axis of ``x @ W``) and axis -1 for
    vectors, applied independently per leading index.
    """
    if w.ndim == 1:
        return _fake_quant_impl(w, cfg.bits, cfg.group_size if cfg.group_size != -1 else w.shape[0])
    if w.ndim == 2:
        return _fake_quant_impl(w, cfg.bits, cfg.resolve_group(w.shape[0]))
    # stacked: vmap over leading axes
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda m: _fake_quant_impl(m, cfg.bits, cfg.resolve_group(w.shape[-2])))(flat)
    return out.reshape(lead + w.shape[-2:])


# ---------------------------------------------------------------------------
# Bit packing (uint32 words along K)
# ---------------------------------------------------------------------------

def vals_per_word(bits: int) -> int:
    return 32 // bits  # 3-bit -> 10 codes/word (2 bits/word wasted)


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack int codes in [0, 2^bits) into uint32 words along axis 0.

    codes: (K, N) int32 with K % vals_per_word == 0 -> (K // vpw, N) uint32.
    """
    vpw = vals_per_word(bits)
    k = codes.shape[0]
    if k % vpw != 0:
        raise ValueError(f"K={k} must be divisible by vals_per_word={vpw}")
    c = codes.reshape((k // vpw, vpw) + codes.shape[1:]).astype(jnp.uint32)
    return functools.reduce(
        jnp.bitwise_or, [c[:, i] << jnp.uint32(i * bits) for i in range(vpw)])


def unpack_codes(packed: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Inverse of pack_codes -> (K, N) int32."""
    vpw = vals_per_word(bits)
    mask = jnp.uint32((1 << bits) - 1)
    parts = [((packed >> jnp.uint32(i * bits)) & mask) for i in range(vpw)]
    c = jnp.stack(parts, axis=1)  # (K//vpw, vpw, ...)
    return c.reshape((c.shape[0] * vpw,) + packed.shape[1:]).astype(jnp.int32)[:k]


# ---------------------------------------------------------------------------
# QTensor: packed storage for the serving path
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Packed, group-quantized tensor.

    ``shape`` is the LOGICAL trailing shape — (K, N) or (K,) — and never
    includes stacking dims, so a stacked QTensor (e.g. scanned layer weights
    with ``packed: (L, K_pad//vpw, N)``) keeps valid metadata when
    ``lax.scan`` slices its arrays along axis 0.

    packed: (..., K_pad // vals_per_word, N) uint32
    scale / zero: (..., K_pad // G, N)
    """

    packed: jnp.ndarray
    scale: jnp.ndarray
    zero: jnp.ndarray
    bits: int
    group_size: int
    shape: tuple  # logical (un-padded, un-stacked) shape

    def tree_flatten(self):
        return (self.packed, self.scale, self.zero), (self.bits, self.group_size, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale, zero = children
        bits, group_size, shape = aux
        return cls(packed, scale, zero, bits, group_size, shape)

    @property
    def dtype(self):
        return jnp.float32

    @property
    def ndim(self):
        return len(self.shape)

    def dequantize(self, out_dtype=jnp.float32) -> jnp.ndarray:
        cfg = QuantConfig(bits=self.bits, group_size=self.group_size)
        k = self.shape[0]
        n = self.shape[1] if len(self.shape) > 1 else 1
        lead = self.packed.shape[:-2]
        vpw = vals_per_word(self.bits)

        def deq2d(packed, scale, zero):
            k_pad = packed.shape[0] * vpw
            codes = unpack_codes(packed, self.bits, k_pad)
            return dequantize_codes(codes, scale, zero, cfg, out_dtype)[:k]

        if not lead:
            w = deq2d(self.packed, self.scale, self.zero)
        else:
            flat = (self.packed.reshape((-1,) + self.packed.shape[-2:]),
                    self.scale.reshape((-1,) + self.scale.shape[-2:]),
                    self.zero.reshape((-1,) + self.zero.shape[-2:]))
            w = jax.vmap(deq2d)(*flat).reshape(lead + (k, n))
        if len(self.shape) == 1:
            w = w[..., 0]
        return w

    def memory_bytes(self) -> int:
        return int(self.packed.size * 4 + self.scale.size * self.scale.dtype.itemsize
                   + self.zero.size * self.zero.dtype.itemsize)


def _quantize_2d(w2: jnp.ndarray, cfg: QuantConfig):
    k = w2.shape[0]
    g = cfg.resolve_group(k)
    vpw = vals_per_word(cfg.bits)
    lcm = int(np.lcm(g, vpw))
    k_pad = lcm * int(np.ceil(k / lcm))
    if k_pad != k:
        w2 = jnp.concatenate([w2, jnp.zeros((k_pad - k, w2.shape[1]), w2.dtype)], axis=0)
    cfg_p = dataclasses.replace(cfg, group_size=g)
    scale, zero = compute_qparams(w2.astype(jnp.float32), cfg_p)
    codes = quantize_codes(w2.astype(jnp.float32), scale, zero, cfg_p)
    packed = pack_codes(codes, cfg.bits)
    return packed, scale, zero, g


def quantize_tensor(w: jnp.ndarray, cfg: QuantConfig) -> QTensor:
    """Quantize + pack a weight into a QTensor.

    (K, N) / (K,) quantize directly; higher-rank (..., K, N) inputs (stacked
    layer or expert weights) are quantized independently per leading index.
    """
    if w.ndim <= 2:
        orig_shape = tuple(w.shape)
        w2 = w if w.ndim == 2 else w[:, None]
        packed, scale, zero, g = _quantize_2d(w2, cfg)
        return QTensor(packed, scale, zero, cfg.bits, g, orig_shape)
    lead = w.shape[:-2]
    logical = tuple(w.shape[-2:])
    flat = w.reshape((-1,) + logical)
    g = cfg.resolve_group(logical[0])

    def q2d(m):
        p, s, z, _ = _quantize_2d(m, cfg)
        return p, s, z
    packed, scale, zero = jax.vmap(q2d)(flat)
    packed = packed.reshape(lead + packed.shape[1:])
    scale = scale.reshape(lead + scale.shape[1:])
    zero = zero.reshape(lead + zero.shape[1:])
    return QTensor(packed, scale, zero, cfg.bits, g, logical)


def bits_per_param(cfg: QuantConfig, scale_bits: int = 16, zero_bits: int = 4) -> float:
    """Effective storage cost (paper Table 3 'Bits/Param' column)."""
    vpw = vals_per_word(cfg.bits)
    code_bits = 32.0 / vpw  # 3-bit stores at 3.2 bits/code
    g = cfg.group_size if cfg.group_size not in (-1, None) else 1 << 30
    return code_bits + (scale_bits + zero_bits) / g
