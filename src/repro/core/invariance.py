"""Invariant transformations (paper §3.2): permutation P, scaling S, rotation R.

Convention: FFN weights are stored JAX-style for ``x @ W`` —
``w_up: (D, F)``, ``w_down: (F, D)``, optional ``w_gate: (D, F)`` (SwiGLU),
optional biases ``b_up/b_gate: (F,)``. The paper's transform

    W̄_up = P S R W_up,   b̄_up = P S R b_up,   W̄_down = W_down Rᵀ S⁻¹ Pᵀ

acts on the hidden (F) axis: columns of up/gate, rows of down. Transforms are
stored compactly as ``(pi, s, phi)`` — a permutation vector, a scale vector and
a rotation-angle vector (paper: "we do not store P, S, R as matrices").

Transforms are always applied to the ORIGINAL parameters (theta_0), with
``(pi, s, phi)`` holding the cumulative transform — this avoids numerical
drift over thousands of accepted search moves.

Exactness (DESIGN.md §Arch-applicability):
  - permutation: exact for any elementwise f (and for gated MLPs when the
    same pi is applied to gate and up);
  - scaling: exact iff f is positively homogeneous (ReLU family); used as the
    paper's approximation mode for SiLU/GeLU;
  - rotation: approximate for any nonlinear f; exact in the limit phi -> 0.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "FFNTransform",
    "identity_transform",
    "apply_rotation_rows",
    "apply_rotation_cols",
    "apply_transform_ffn",
    "apply_transform_mamba",
    "propose",
    "ProposalConfig",
]


class FFNTransform(NamedTuple):
    """Cumulative per-layer transform. pi: (F,) int32; s: (F,) f32; phi: (F//2,) f32."""

    pi: jnp.ndarray
    s: jnp.ndarray
    phi: jnp.ndarray


def identity_transform(f_dim: int) -> FFNTransform:
    return FFNTransform(
        pi=jnp.arange(f_dim, dtype=jnp.int32),
        s=jnp.ones((f_dim,), jnp.float32),
        phi=jnp.zeros((f_dim // 2,), jnp.float32),
    )


def _rotate_pairs(w: jnp.ndarray, phi: jnp.ndarray, axis: int, inverse: bool) -> jnp.ndarray:
    """Apply block-diagonal Givens rotation R (Eqn. 20) along ``axis`` of w.

    Pairs are (2i, 2i+1). ``inverse`` applies R^T.
    """
    w = jnp.moveaxis(w, axis, 0)
    f = w.shape[0]
    wp = w.reshape((f // 2, 2) + w.shape[1:])
    c, s = jnp.cos(phi), jnp.sin(phi)
    if inverse:
        s = -s
    shape = (f // 2,) + (1,) * (w.ndim - 1)
    c = c.reshape(shape)
    s = s.reshape(shape)
    a, b = wp[:, 0], wp[:, 1]
    ra = c * a - s * b
    rb = s * a + c * b
    out = jnp.stack([ra, rb], axis=1).reshape(w.shape)
    return jnp.moveaxis(out, 0, axis)


def apply_rotation_rows(w: jnp.ndarray, phi: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """R @ w for w whose FIRST axis is the rotated (F) axis."""
    return _rotate_pairs(w, phi, axis=0, inverse=inverse)


def apply_rotation_cols(w: jnp.ndarray, phi: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """w @ Rᵀ for w whose SECOND axis is the rotated (F) axis (up/gate
    column convention; the fused transform+fake-quant kernel's oracle)."""
    return _rotate_pairs(w, phi, axis=1, inverse=inverse)


def apply_transform_ffn(
    t: FFNTransform,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    b_up: Optional[jnp.ndarray] = None,
    w_gate: Optional[jnp.ndarray] = None,
    b_gate: Optional[jnp.ndarray] = None,
):
    """Return (w_up', w_down', b_up', w_gate', b_gate') = PSR-transformed params.

    Shapes: w_up/w_gate (D, F); w_down (F, D); b_up/b_gate (F,).
    Order (paper Eqns. 21-22): rotate, then scale, then permute on the F axis;
    the inverse order on w_down rows.
    """
    # --- up projection columns: R, S, P
    up = apply_rotation_cols(w_up, t.phi)
    up = up * t.s[None, :]
    up = up[:, t.pi]
    # --- down projection rows. Paper: W̄_down = W_down Rᵀ S⁻¹ Pᵀ with
    # W_down: (D, F); ours is the transpose (F, D), so the row ops are
    # down' = P S⁻¹ R · down — note FORWARD R on rows ((W Rᵀ)ᵀ = R Wᵀ).
    down = _rotate_pairs(w_down, t.phi, axis=0, inverse=False)
    down = down * (1.0 / t.s)[:, None]
    down = down[t.pi, :]
    out_b_up = None
    if b_up is not None:
        b = apply_rotation_rows(b_up, t.phi) * t.s
        out_b_up = b[t.pi]
    out_gate = None
    out_b_gate = None
    if w_gate is not None:
        # gated MLP: act(x@Wg) * (x@Wu) — the SAME permutation must hit both;
        # scaling/rotation are applied to the gate branch only through P (the
        # elementwise product makes S/R on 'up' alone the invariant choice:
        # scaling columns of up by s and rows of down by 1/s is exact for the
        # linear 'up' branch; the gate branch is only permuted).
        out_gate = w_gate[:, t.pi]
        if b_gate is not None:
            out_b_gate = b_gate[t.pi]
    return up, down, out_b_up, out_gate, out_b_gate


def invert_permutation(pi: jnp.ndarray) -> jnp.ndarray:
    inv = jnp.zeros_like(pi)
    return inv.at[pi].set(jnp.arange(pi.shape[0], dtype=pi.dtype))


def apply_transform_mamba(
    pi: jnp.ndarray,
    w_in_x: jnp.ndarray,
    w_in_z: jnp.ndarray,
    conv_x: jnp.ndarray,
    w_out: jnp.ndarray,
    head_dim: int,
):
    """Within-head channel permutation for a Mamba2 block (beyond-paper; see
    DESIGN.md §Arch-applicability).

    pi must be block-structured: it permutes channels only WITHIN each head of
    size ``head_dim`` (callers construct it that way). Then the depthwise conv
    filters, the z (gate) columns, the x columns and the out_proj rows move
    together and the block is exactly invariant.

    Shapes: w_in_x / w_in_z: (D, d_inner); conv_x: (width, d_inner);
    w_out: (d_inner, D).
    """
    return (
        w_in_x[:, pi],
        w_in_z[:, pi],
        conv_x[:, pi],
        w_out[pi, :],
    )


# ---------------------------------------------------------------------------
# Proposal sampling (Algorithm 1, lines 11-14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProposalConfig:
    """Random-walk hyper-parameters (paper §4.1)."""

    sigma_s: float = 1e-2
    sigma_r: float = 1e-5
    subset_frac: float = 0.10  # move ~10% of neurons per step (paper §3.2)
    use_permutation: bool = True
    use_scaling: bool = True
    use_rotation: bool = True


def _partial_shuffle(key, pi: jnp.ndarray, n_move: int) -> jnp.ndarray:
    """Shuffle a random subset of ``n_move`` entries of pi among themselves.

    jit-friendly: n_move is static. Picks the first n_move indices of a random
    permutation of positions, then cyclically reassigns their values through a
    second random permutation.
    """
    f = pi.shape[0]
    k1, k2 = jax.random.split(key)
    pos = jax.random.permutation(k1, f)[:n_move]          # which slots move
    order = jax.random.permutation(k2, n_move)            # how they exchange
    vals = pi[pos]
    return pi.at[pos].set(vals[order])


def propose(key, t: FFNTransform, cfg: ProposalConfig) -> FFNTransform:
    """Sample a candidate transform centered on the current one."""
    f = t.pi.shape[0]
    n_move = max(2, int(round(cfg.subset_frac * f)))
    n_rot = max(1, int(round(cfg.subset_frac * (f // 2))))
    k_p, k_s, k_sm, k_r, k_rm = jax.random.split(key, 5)

    pi = t.pi
    if cfg.use_permutation:
        pi = _partial_shuffle(k_p, t.pi, n_move)

    s = t.s
    if cfg.use_scaling:
        noise = jax.random.normal(k_s, (f,)) * cfg.sigma_s
        mask = jnp.zeros((f,)).at[jax.random.permutation(k_sm, f)[:n_move]].set(1.0)
        s = jnp.maximum(t.s + noise * mask, 1e-3)

    phi = t.phi
    if cfg.use_rotation:
        noise = jax.random.normal(k_r, (f // 2,)) * cfg.sigma_r
        mask = jnp.zeros((f // 2,)).at[jax.random.permutation(k_rm, f // 2)[:n_rot]].set(1.0)
        phi = t.phi + noise * mask

    return FFNTransform(pi=pi, s=s, phi=phi)
