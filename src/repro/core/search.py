"""Adapters + front-end for the discrete search (paper Algorithm 1).

The search loop itself lives in ``repro.search.engine`` — a population ×
island annealed engine whose ``population=1, islands=1, temperature=0``
defaults reproduce the original single-chain hill climb bit-for-bit. This
module keeps what is model-family-specific: the *adapters* that expose a
family's transformable units (dense FFN, MoE expert, Mamba block, shared
hybrid FFN) plus the ``run_search`` entry point every caller already uses.

TPU-native execution model (DESIGN.md §3): the whole proposal evaluation —
transform → fake-quant → forward → loss — is ONE jitted function with the
unit index as a traced scalar, so a single XLA program serves every step.
Proposals come from counter-based ``jax.random`` keys: in a multi-host
setting every host replays the same proposal stream and the accept decision
derives from the (all-reduced) scalar loss, so hosts stay in lock-step with
zero extra communication.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import invariance as inv
from repro.core.quant import QuantConfig, fake_quant
from repro.models.config import ModelConfig

__all__ = ["SearchConfig", "SearchResult", "DenseFFNAdapter", "MoEAdapter",
           "MambaAdapter", "run_search"]


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    steps: int = 2000
    seed: int = 0
    # registry name ("ce" Eqn. 23 | "kl" Algorithm-1 listing | "swd_actmatch"
    # | "saliency_ce") or a core.objective.Objective instance
    objective: Any = "ce"
    n_match_layers: int = 10       # activation-matching depth (paper Table 4)
    ce_weight: float = 10.0        # CE is 10x more important at step 0 (§4.1)
    proposal: inv.ProposalConfig = dataclasses.field(default_factory=inv.ProposalConfig)
    log_every: int = 200
    # --- engine scale-out (repro.search); defaults = legacy behavior ---
    population: int = 1            # candidates per step, one batched eval
    islands: int = 1               # independent chains (data-axis parallel)
    temperature: float = 0.0       # initial annealing T; 0 = greedy climb
    anneal: str = "geometric"      # schedule: constant | geometric | linear
    migrate_every: int = 50        # elite-migration cadence (0 = never)
    fused_kernel: bool = False     # kernels.transform_quant fused hot path
    mapped: bool = False           # one island per mesh shard (shard_map);
                                   # requires islands == global device count
    # --- v2 candidate-eval memory model + calibration sharding ---
    install: str = "unit"          # "unit": stack + K×unit dynamic-slice
                                   # install; "stack": v1 K full stacks
    tabu: int = 0                  # tried-point memory capacity (0 = off;
                                   # sequential lane only)
    shard_calib: bool = False      # per-island calibration slices
    measure_memory: bool = False   # sample jax.live_arrays() peaks into
                                   # stats["peak_live_bytes"] (slow; bench)


@dataclasses.dataclass
class SearchResult:
    params_q: dict                 # model with searched fake-quant weights installed
    transforms: inv.FFNTransform   # stacked per-unit transforms
    history: list                  # (step, loss, ce, mse, accepted)
    accept_rate: float
    final_loss: float
    initial_loss: float
    island_histories: Optional[list] = None  # per-island histories (engine)
    stats: Optional[dict] = None   # migrations / uphill accepts / proposals-per-sec


def _tree_slice(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _tree_update(tree, i, new):
    return jax.tree.map(lambda x, n: x.at[i].set(n), tree, new)


# ---------------------------------------------------------------------------
# Adapters: expose a model family's transformable units to the search
# ---------------------------------------------------------------------------

class DenseFFNAdapter:
    """Dense decoder blocks: unit = one FFN (up[/gate]/down[,b_up,b_gate])."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_units = cfg.n_layers
        self.f_dim = cfg.d_ff

    def base_stack(self, params):
        mlp = params["blocks"]["mlp"]
        return {k: mlp[k] for k in ("up", "down", "gate", "b_up", "b_gate") if k in mlp}

    def transform_unit(self, base, t: inv.FFNTransform, u):
        b = _tree_slice(base, u)
        up, down, b_up, gate, b_gate = inv.apply_transform_ffn(
            t, b["up"], b["down"], b.get("b_up"), b.get("gate"), b.get("b_gate"))
        out = {"up": up, "down": down}
        if b_up is not None:
            out["b_up"] = b_up
        if gate is not None:
            out["gate"] = gate
        if b_gate is not None:
            out["b_gate"] = b_gate
        return out

    def quant_unit(self, unit, qcfg: QuantConfig):
        out = {}
        for k, v in unit.items():
            out[k] = fake_quant(v, qcfg) if v.ndim >= 2 else v
        return out

    def transform_quant_unit(self, base, t: inv.FFNTransform, u, qcfg: QuantConfig):
        """Fused hot path: (π, s, φ) + group fake-quant in ONE kernel pass per
        weight (``kernels.transform_quant``) instead of materializing the
        transformed fp32 weights and re-reading them to quantize. Biases are
        tiny and stay on the jnp path (they are never quantized)."""
        from repro.kernels import transform_quant
        b = _tree_slice(base, u)
        ident_s = jnp.ones_like(t.s)
        ident_phi = jnp.zeros_like(t.phi)
        out = {}
        for k, s_vec, phi_vec in (("up", t.s, t.phi),
                                  ("gate", ident_s, ident_phi)):
            if k in b:  # gate branch is permuted only (see apply_transform_ffn)
                out[k] = transform_quant(
                    b[k], t.pi, s_vec, phi_vec, bits=qcfg.bits,
                    group=qcfg.resolve_group(b[k].shape[0]), mode="up")[0]
        out["down"] = transform_quant(
            b["down"], t.pi, t.s, t.phi, bits=qcfg.bits,
            group=qcfg.resolve_group(b["down"].shape[0]), mode="down")[0]
        if "b_up" in b:
            out["b_up"] = (inv.apply_rotation_rows(b["b_up"], t.phi) * t.s)[t.pi]
        if "b_gate" in b:
            out["b_gate"] = b["b_gate"][t.pi]
        return out

    def install(self, params, fq_stack):
        params = dict(params)
        blocks = dict(params["blocks"])
        blocks["mlp"] = {**blocks["mlp"], **fq_stack}
        params["blocks"] = blocks
        return params


class MoEAdapter:
    """MoE blocks: unit = one expert's FFN. n_units = L * E (per-expert search
    — under expert parallelism each shard searches its own experts)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.E = cfg.moe.num_experts
        self.n_units = cfg.n_layers * self.E
        self.f_dim = cfg.d_ff

    def base_stack(self, params):
        moe = params["blocks"]["moe"]
        # (L, E, ...) -> (L*E, ...) unit-major
        return {k: moe[k].reshape((-1,) + moe[k].shape[2:])
                for k in ("up", "down", "gate") if k in moe}

    def transform_unit(self, base, t, u):
        b = _tree_slice(base, u)
        up, down, _, gate, _ = inv.apply_transform_ffn(
            t, b["up"], b["down"], None, b.get("gate"), None)
        out = {"up": up, "down": down}
        if gate is not None:
            out["gate"] = gate
        return out

    def quant_unit(self, unit, qcfg):
        return {k: fake_quant(v, qcfg) for k, v in unit.items()}

    # per-expert units carry the same up/down[/gate] layout as a dense FFN,
    # so the fused transform+fake-quant path applies unchanged
    transform_quant_unit = DenseFFNAdapter.transform_quant_unit

    def install(self, params, fq_stack):
        params = dict(params)
        blocks = dict(params["blocks"])
        moe = dict(blocks["moe"])
        L = self.cfg.n_layers
        for k, v in fq_stack.items():
            moe[k] = v.reshape((L, self.E) + v.shape[1:])
        blocks["moe"] = moe
        params["blocks"] = blocks
        return params


class MambaAdapter:
    """Mamba2 blocks: unit = one block; permutation-only, block-structured
    within heads (exact invariance — DESIGN.md §Arch-applicability)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # hybrid stacks: only the mamba blocks are units of this adapter
        self.n_units = (cfg.hybrid_layout()[0] if cfg.block_pattern == "hybrid"
                        else cfg.n_layers)
        s = cfg.ssm
        self.di = s.d_inner(cfg.d_model)
        self.head_dim = s.head_dim
        self.f_dim = self.di

    def base_stack(self, params):
        ssm = params["blocks"]["ssm"]
        return {k: ssm[k] for k in ("w_z", "w_x", "conv_x", "conv_b_x",
                                    "norm_w", "out_proj")}

    def transform_unit(self, base, t: inv.FFNTransform, u):
        b = _tree_slice(base, u)
        pi = t.pi  # MUST be within-head block structured (self.propose)
        return {
            "w_z": b["w_z"][:, pi],
            "w_x": b["w_x"][:, pi],
            "conv_x": b["conv_x"][:, pi],
            "conv_b_x": b["conv_b_x"][pi],
            "norm_w": b["norm_w"][pi],
            "out_proj": b["out_proj"][pi, :],
        }

    def quant_unit(self, unit, qcfg):
        out = dict(unit)
        out["w_z"] = fake_quant(unit["w_z"], qcfg)
        out["w_x"] = fake_quant(unit["w_x"], qcfg)
        out["out_proj"] = fake_quant(unit["out_proj"], qcfg)
        return out

    def install(self, params, fq_stack):
        params = dict(params)
        blocks = dict(params["blocks"])
        blocks["ssm"] = {**blocks["ssm"], **fq_stack}
        params["blocks"] = blocks
        return params

    def propose(self, key, t: inv.FFNTransform, pcfg: inv.ProposalConfig) -> inv.FFNTransform:
        """Within-head partial shuffle: pick one head, shuffle a fraction."""
        hd = self.head_dim
        n_heads = self.di // hd
        n_move = max(2, int(round(pcfg.subset_frac * hd)))
        k1, k2, k3 = jax.random.split(key, 3)
        head = jax.random.randint(k1, (), 0, n_heads)
        pos_in_head = jax.random.permutation(k2, hd)[:n_move] + head * hd
        order = jax.random.permutation(k3, n_move)
        vals = t.pi[pos_in_head]
        pi = t.pi.at[pos_in_head].set(vals[order])
        return inv.FFNTransform(pi=pi, s=t.s, phi=t.phi)


class SharedFFNAdapter:
    """Hybrid (Zamba2): the ONE shared attention block's FFN as a single unit
    (its weights are shared across all applications, so one transform covers
    every application exactly)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_units = 1
        self.f_dim = cfg.d_ff

    def base_stack(self, params):
        mlp = params["shared"]["mlp"]
        keep = {k: mlp[k] for k in ("up", "down", "gate", "b_up", "b_gate") if k in mlp}
        return jax.tree.map(lambda x: x[None], keep)  # stack dim of 1

    def transform_unit(self, base, t: inv.FFNTransform, u):
        b = _tree_slice(base, u)
        up, down, b_up, gate, b_gate = inv.apply_transform_ffn(
            t, b["up"], b["down"], b.get("b_up"), b.get("gate"), b.get("b_gate"))
        out = {"up": up, "down": down}
        if b_up is not None:
            out["b_up"] = b_up
        if gate is not None:
            out["gate"] = gate
        if b_gate is not None:
            out["b_gate"] = b_gate
        return out

    quant_unit = DenseFFNAdapter.quant_unit

    def install(self, params, fq_stack):
        params = dict(params)
        shared = dict(params["shared"])
        shared["mlp"] = {**shared["mlp"],
                         **jax.tree.map(lambda x: x[0], fq_stack)}
        params["shared"] = shared
        return params


def make_adapter(cfg: ModelConfig, phase: str = None):
    if cfg.block_pattern in ("dense",):
        return DenseFFNAdapter(cfg)
    if cfg.block_pattern == "moe":
        return MoEAdapter(cfg)
    if cfg.block_pattern == "ssm":
        return MambaAdapter(cfg)
    if cfg.block_pattern == "hybrid":
        # two-phase composite: "mamba" (within-head P, exact) then "shared"
        # (full P/S/R on the shared block's FFN) — see run_search_hybrid.
        if phase == "shared":
            return SharedFFNAdapter(cfg)
        return MambaAdapter(cfg)
    raise NotImplementedError(f"no search adapter for pattern {cfg.block_pattern!r}")


def _merge_phase_stats(s1, s2):
    """Sum the counters of two engine stats dicts; rates recombine so the
    merged proposals_per_sec reflects TOTAL proposals over TOTAL wall time."""
    if s1 is None or s2 is None:
        return s2 or s1
    out = dict(s2)
    for k in ("migrations", "uphill_accepts", "proposals"):
        out[k] = s1.get(k, 0) + s2.get(k, 0)
    t1 = s1.get("proposals", 0) / max(s1.get("proposals_per_sec", 0.0), 1e-9)
    t2 = s2.get("proposals", 0) / max(s2.get("proposals_per_sec", 0.0), 1e-9)
    out["proposals_per_sec"] = out["proposals"] / max(t1 + t2, 1e-9)
    out["fused"] = s1.get("fused", False) or s2.get("fused", False)
    return out


def run_search_hybrid(params_fp, params_base, cfg, qcfg, calib_tokens,
                      scfg: SearchConfig = SearchConfig(), forward_kwargs=None):
    """Deprecated: ``repro.search.run`` dispatches hybrid block patterns to
    the two-phase Mamba → shared-FFN composite automatically."""
    warnings.warn(
        "core.search.run_search_hybrid is deprecated; use "
        "repro.search.run(...) (hybrid configs two-phase automatically)",
        DeprecationWarning, stacklevel=2)
    from repro.search import run
    return run(params_fp, params_base, cfg, qcfg, calib_tokens, scfg,
               forward_kwargs=forward_kwargs, hybrid=True)


# ---------------------------------------------------------------------------
# The search entry point (Algorithm 1) — deprecated shim over repro.search
# ---------------------------------------------------------------------------

def run_search(
    params_fp: dict,
    params_base: dict,
    cfg: ModelConfig,
    qcfg: QuantConfig,
    calib_tokens: jnp.ndarray,
    scfg: SearchConfig = SearchConfig(),
    adapter=None,
    forward_kwargs: Optional[dict] = None,
) -> SearchResult:
    """Deprecated: call ``repro.search.run`` (same signature, one front door
    for single-phase, hybrid and population/island configurations).

    This shim preserves the legacy single-phase semantics exactly — on a
    hybrid config it searches only the Mamba blocks, as before (pass the
    config to ``repro.search.run`` without an adapter to get the two-phase
    composite instead). The default ``SearchConfig`` (population=1,
    islands=1, temperature=0) reproduces the original single-chain hill
    climb bit-for-bit.
    """
    warnings.warn(
        "core.search.run_search is deprecated; use repro.search.run(...)",
        DeprecationWarning, stacklevel=2)
    from repro.search import run
    return run(params_fp, params_base, cfg, qcfg, calib_tokens, scfg,
               adapter=adapter, forward_kwargs=forward_kwargs, hybrid=False)
