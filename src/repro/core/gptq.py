"""GPTQ baseline (Frantar et al. 2023) in JAX.

Per linear weight W (K, N) used as ``x @ W`` with calibration inputs
X (n, K): sequentially quantize input-dim rows; after quantizing row k, the
remaining rows absorb the rounding error weighted by the inverse-Hessian:

    H      = 2 XᵀX + λI            (λ = damp · mean(diag H))
    U      = cholesky(H⁻¹)ᵀ        (upper factor, as in the reference code)
    err_k  = (W[k] - dq(W[k])) / U[k, k]
    W[j]  -= U[k, j] · err_k        for j > k

Group scale/zero are (re)computed from the *updated* weights at each group
boundary. The whole inner loop is a ``lax.fori_loop``; layers are vmapped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.core.taps import capture_dense_taps
from repro.models.config import ModelConfig

__all__ = ["gptq_matrix", "gptq_process_dense"]


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "damp"))
def gptq_matrix(w, x, bits: int, group_size: int, damp: float = 0.01):
    """w: (K, N); x: (n, K) calibration inputs. Returns dequantized-domain
    GPTQ-compensated weights (K, N)."""
    K, N = w.shape
    G = group_size if group_size != -1 else K
    q_max = (1 << bits) - 1

    xf = x.astype(jnp.float32)
    H = 2.0 * (xf.T @ xf)
    diag_mean = jnp.mean(jnp.diag(H))
    H = H + (damp * diag_mean + 1e-6) * jnp.eye(K)
    Hinv = jax.scipy.linalg.cho_solve((jnp.linalg.cholesky(H), True), jnp.eye(K))
    # symmetrize for numerical safety before the second factorization
    Hinv = 0.5 * (Hinv + Hinv.T) + 1e-8 * jnp.eye(K)
    U = jnp.linalg.cholesky(Hinv).T                       # upper: Hinv = UᵀU

    w0 = w.astype(jnp.float32)

    def qparams(rows):
        wmax = jnp.max(rows, axis=0)
        wmin = jnp.min(rows, axis=0)
        scale = jnp.maximum((wmax - wmin) / q_max, 1e-8)
        zero = jnp.clip(jnp.round(-wmin / scale), 0, q_max)
        return scale, zero

    def body(k, carry):
        W, dq, scale, zero = carry
        # refresh group qparams at boundaries from the CURRENT weights
        def refresh(_):
            g0 = (k // G) * G
            rows = jax.lax.dynamic_slice(W, (g0, 0), (G, N))
            return qparams(rows)
        scale, zero = jax.lax.cond(k % G == 0, refresh, lambda _: (scale, zero), None)
        wk = W[k]
        q = jnp.clip(jnp.round(wk / scale) + zero, 0, q_max)
        dqk = scale * (q - zero)
        err = (wk - dqk) / U[k, k]
        # update remaining rows j > k
        ucol = jnp.where(jnp.arange(K) > k, U[k], 0.0)    # (K,)
        W = W - ucol[:, None] * err[None, :]
        dq = dq.at[k].set(dqk)
        return W, dq, scale, zero

    s0, z0 = qparams(jax.lax.dynamic_slice(w0, (0, 0), (G, N)))
    _, dq, _, _ = jax.lax.fori_loop(0, K, body, (w0, jnp.zeros_like(w0), s0, z0))
    return dq.astype(w.dtype)


def gptq_process_dense(params, cfg: ModelConfig, calib_tokens, qcfg: QuantConfig,
                       damp: float = 0.01):
    """Run GPTQ over every quantizable linear of a dense decoder.

    Returns params with all attn/mlp weights replaced by GPTQ-compensated
    dequantized-domain weights. (They lie on the quantization grid, so a
    subsequent ``fake_quant`` with the same config is ~idempotent; the search
    re-quantizes transformed versions of them.)
    """
    taps = capture_dense_taps(params, cfg, calib_tokens)

    def flat(t):  # (L,B,S,D) -> (L, B*S, D)
        return t.reshape(t.shape[0], -1, t.shape[-1])

    x_attn = flat(taps["attn_in"])
    x_wo = flat(taps["attn_mid"])
    x_mlp = flat(taps["mlp_in"])
    x_down = flat(taps["mlp_mid"])

    run = jax.vmap(lambda w, x: gptq_matrix(w, x, qcfg.bits, qcfg.group_size, damp))

    blocks = dict(params["blocks"])
    attn = dict(blocks["attn"])
    attn["wq"] = run(attn["wq"], x_attn)
    attn["wk"] = run(attn["wk"], x_attn)
    attn["wv"] = run(attn["wv"], x_attn)
    attn["wo"] = run(attn["wo"], x_wo)
    blocks["attn"] = attn
    mlp = dict(blocks["mlp"])
    mlp["up"] = run(mlp["up"], x_mlp)
    if "gate" in mlp:
        mlp["gate"] = run(mlp["gate"], x_mlp)
    mlp["down"] = run(mlp["down"], x_down)
    blocks["mlp"] = mlp
    out = dict(params)
    out["blocks"] = blocks
    return out
