"""Round-to-nearest baseline: plain asymmetric group quantization of every
quantizable weight (paper Table 1, 'RTN')."""
from __future__ import annotations


from repro.core.quant import QuantConfig, fake_quant
from repro.models.model import quantizable_paths

__all__ = ["rtn_quantize", "get_by_path", "set_by_path", "map_quantizable"]


def get_by_path(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def set_by_path(tree, path, value):
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = set_by_path(tree[path[0]], path[1:], value)
    return out


def map_quantizable(params, fn, only=None):
    """Apply fn(leaf, path) to every quantizable weight leaf."""
    out = params
    for path in quantizable_paths(params):
        if only is not None and not only(path):
            continue
        out = set_by_path(out, path, fn(get_by_path(out, path), path))
    return out


def rtn_quantize(params, qcfg: QuantConfig, only=None):
    return map_quantizable(params, lambda w, _: fake_quant(w, qcfg), only=only)
