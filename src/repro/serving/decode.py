"""Jit-able paged decode step: one token for every batch slot, KV in pages.

The step mirrors ``repro.models.model.decode_step``'s scanned layer stack but
replaces the contiguous-cache attention with the paged path:

  1. scatter-write this step's K/V (quantized to int8 when configured) into
     each sequence's current page at ``(block_table[b, pos // psz], pos % psz)``
  2. attend over the pool through ``kernels.paged_decode`` (block table +
     per-sequence lengths scalar-prefetched into the Pallas grid)

Unlike the dense step, positions are PER-SEQUENCE (``seq_lens`` (B,)) — the
whole point of continuous batching is that batch slots sit at unrelated
depths. Idle slots carry ``seq_len == 0`` and a null-page block table: their
write lands in the reserved page and their attention output is fully masked.

Token selection is greedy by default; ``temperature > 0`` switches the step
to temperature / top-k sampling with PER-SEQUENCE RNG keys threaded through
the jitted step (the key array is an extra step argument, so one compiled
program serves every step and re-seeding a sequence is just handing it a new
key row). Greedy steps keep the original 5-argument signature byte-for-byte.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import paged_decode
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.model import dequant_tree, embed_tokens, lm_head_logits

__all__ = ["make_paged_decode_step", "paged_attention_block",
           "paged_block_body", "sample_logits", "sample_logits_per_seq",
           "sample_step_keys", "request_key"]


def sample_step_keys(key, batch: int):
    """(B, 2) uint32 per-sequence keys for one sampling step."""
    return jax.random.split(key, batch)


def request_key(seed: int, token_index: int):
    """The RNG key for a request's ``token_index``-th generated token.

    Derived from (seed, token index) ALONE — not from how many decode steps
    actually ran — so a recompute-preempted request resumes its sample stream
    exactly where it left off: the re-admit's first sampled token uses the
    same key the uninterrupted decode step would have used.
    """
    return jax.random.fold_in(jax.random.PRNGKey(seed), token_index)


def sample_logits(logits, keys, *, temperature: float, top_k: int = 0):
    """Per-sequence temperature / top-k sampling.

    logits (B, V); keys (B, 2) uint32 (one key row per sequence, e.g. from
    ``sample_step_keys``). ``top_k > 0`` restricts sampling to the k highest
    logits; ``temperature <= 0`` degenerates to greedy argmax. Returns (B,)
    int32 — deterministic in (logits, keys).
    """
    logits = logits.astype(jnp.float32)
    if top_k and top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def sample_logits_per_seq(logits, keys, temperature, top_k):
    """Per-SEQUENCE temperature / top-k sampling (params as (B,) arrays).

    The batcher's mixed-batch path: each slot carries its own ``temperature``
    (f32) and ``top_k`` (int32), so one compiled step serves any mix of
    greedy and sampled requests. Slots with ``temperature <= 0`` take the
    exact argmax (identical to the greedy step's selection); ``top_k == 0``
    means unrestricted. Per-row thresholds come from a full descending sort
    (k is per-row, so ``lax.top_k``'s static k does not apply); for a row
    with top_k == k the kept set matches ``sample_logits``'s
    ``lax.top_k``-derived threshold exactly.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    srt = jnp.sort(logits, axis=-1)[:, ::-1]             # descending
    kth = jnp.take_along_axis(
        srt, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    restricted = jnp.where(logits < kth, -jnp.inf, logits)
    eff = jnp.where((top_k > 0)[:, None], restricted, logits)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
    sampled = jax.vmap(jax.random.categorical)(keys, eff / safe_t[:, None])
    return jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)


def _write_token(pool, phys, slot, val):
    """pool (N, psz, ...) <- val (B, ...) at (phys[b], slot[b]) per slot b."""
    return pool.at[phys, slot].set(val.astype(pool.dtype))


def paged_block_body(pl, cfg: ModelConfig, carry, pool_slice, attn_sublayer):
    """One dense/moe block over a paged pool slice, shared by BOTH paged
    serving stacks — ``attn_sublayer(attn_params, normed_x, pool_slice) ->
    (attn_out, new_pool)`` is the ONLY difference between the decode step
    (single-token scatter-write) and the prefill chunk step (chunk write).
    Keeping one body here is what guarantees their numerics cannot drift
    (the prefill<=1e-5 equivalence and preemption determinism depend on a
    re-admitted request resuming through the same block math)."""
    pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
    a_in = L.apply_norm(carry, pl["ln1"], cfg.norm)
    a, new_pool = attn_sublayer(pl["attn"], a_in, pool_slice)
    hh = carry + a
    m_in = L.apply_norm(hh, pl["ln2"], cfg.norm)
    if "moe" in pl:
        hh = hh + L.moe_ffn(pl["moe"], cfg, m_in)
    else:
        hh = hh + L.mlp(pl["mlp"], cfg, m_in)
    return hh, new_pool


def paged_attention_block(p, cfg: ModelConfig, x, pools, block_tables,
                          seq_lens, *, use_pallas: bool = True,
                          gqa_pages_per_block: int = 1):
    """Attention sublayer over the paged cache (one layer's pool slices).

    x: (B, 1, D) normed input; pools: {"k"/"v": (N, psz, Hkv, hd)[, scales]}.
    Returns (attn_out (B, 1, D), updated pools). ``gqa_pages_per_block``
    batches the fused-GQA kernel's inner softmax over page blocks (1 keeps
    the single-page grid bit-for-bit).
    """
    positions = seq_lens[:, None]                       # (B, 1) write position
    q, k, v = L.attn_qkv(p, cfg, x, positions)
    psz = pools["k"].shape[1]
    phys = jnp.take_along_axis(block_tables, (seq_lens // psz)[:, None],
                               axis=1)[:, 0]            # (B,) physical page
    slot = seq_lens % psz
    new = dict(pools)
    if "k_scale" in pools:  # int8 pool: same convention as the dense cache
        kq, vq, ks, vs = L.quantize_kv(k, v)
        new["k"] = _write_token(pools["k"], phys, slot, kq[:, 0])
        new["v"] = _write_token(pools["v"], phys, slot, vq[:, 0])
        new["k_scale"] = _write_token(pools["k_scale"], phys, slot, ks[:, 0])
        new["v_scale"] = _write_token(pools["v_scale"], phys, slot, vs[:, 0])
    else:
        new["k"] = _write_token(pools["k"], phys, slot, k[:, 0])
        new["v"] = _write_token(pools["v"], phys, slot, v[:, 0])
    out = paged_decode(q[:, 0], new["k"], new["v"], block_tables, seq_lens + 1,
                       new.get("k_scale"), new.get("v_scale"),
                       use_pallas=use_pallas,
                       gqa_pages_per_block=gqa_pages_per_block)
    return L.attn_out(p, out[:, None].astype(q.dtype), cfg), new


def make_paged_decode_step(cfg: ModelConfig, *, use_pallas: bool = True,
                           temperature: float = 0.0, top_k: int = 0,
                           per_request: bool = False,
                           gqa_pages_per_block: int = 1):
    """(params_q, tokens (B,1), pools, block_tables (B,P), seq_lens (B,))
    -> (next_token (B,1) int32, updated pools).

    ``pools`` leaves carry a leading n_layers axis and are scanned alongside
    the stacked layer params, exactly like the dense cache in
    ``model.decode_step``. Only attention-cache architectures page.

    With ``temperature > 0`` the returned step takes one extra trailing
    argument, ``sample_keys`` (B, 2) uint32 per-sequence keys, and samples
    through ``sample_logits`` (optionally top-k-restricted); the default
    greedy step keeps the original signature and argmax selection unchanged.

    ``per_request=True`` instead appends FOUR trailing arguments — ``seeds``
    (B,) int32, ``token_indices`` (B,) int32, ``temperatures`` (B,) f32 and
    ``top_ks`` (B,) int32. Keys are folded from (seed, token index) inside
    the compiled step (``request_key``) and selection routes through
    ``sample_logits_per_seq``, so a single program serves any per-slot mix
    of greedy and sampled requests (the continuous batcher's path).
    """
    if cfg.block_pattern not in ("dense", "moe"):
        raise ValueError(f"paged decode requires attention blocks, "
                         f"got {cfg.block_pattern!r}")
    if cfg.is_enc_dec:
        raise ValueError("paged decode does not cover cross-attention caches")

    def logits_step(params_q, tokens, pools, block_tables, seq_lens):
        positions = seq_lens[:, None]
        h = embed_tokens(params_q, cfg, tokens, positions)

        def attn(p, x, pool_slice):
            return paged_attention_block(
                p, cfg, x, pool_slice, block_tables, seq_lens,
                use_pallas=use_pallas,
                gqa_pages_per_block=gqa_pages_per_block)

        def body(carry, xs):
            pl, pool_slice = xs
            return paged_block_body(pl, cfg, carry, pool_slice, attn)

        h, new_pools = jax.lax.scan(body, h, (params_q["blocks"], pools),
                                    unroll=cfg.unroll_layers)
        return lm_head_logits(params_q, cfg, h, mask_vocab=True), new_pools

    if per_request:
        def per_request_step(params_q, tokens, pools, block_tables, seq_lens,
                             seeds, token_indices, temperatures, top_ks):
            logits, new_pools = logits_step(params_q, tokens, pools,
                                            block_tables, seq_lens)
            # keys are derived INSIDE the compiled step from (seed, token
            # index) — the batcher ships two int vectors instead of running
            # B tiny key-fold programs (device round trips) per decode step
            keys = jax.vmap(request_key)(seeds, token_indices)
            next_tok = sample_logits_per_seq(logits[:, -1], keys,
                                             temperatures, top_ks)
            return next_tok[:, None], new_pools
        return per_request_step

    if temperature > 0.0:
        def sampled_step(params_q, tokens, pools, block_tables, seq_lens,
                         sample_keys):
            logits, new_pools = logits_step(params_q, tokens, pools,
                                            block_tables, seq_lens)
            next_tok = sample_logits(logits[:, -1], sample_keys,
                                     temperature=temperature, top_k=top_k)
            return next_tok[:, None], new_pools
        return sampled_step

    def step(params_q, tokens, pools, block_tables, seq_lens):
        logits, new_pools = logits_step(params_q, tokens, pools, block_tables,
                                        seq_lens)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_pools

    return step
