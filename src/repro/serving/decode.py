"""Jit-able paged decode step: one token for every batch slot, KV in pages.

The step mirrors ``repro.models.model.decode_step``'s scanned layer stack but
replaces the contiguous-cache attention with the paged path:

  1. scatter-write this step's K/V (quantized to int8 when configured) into
     each sequence's current page at ``(block_table[b, pos // psz], pos % psz)``
  2. attend over the pool through ``kernels.paged_decode`` (block table +
     per-sequence lengths scalar-prefetched into the Pallas grid)

Unlike the dense step, positions are PER-SEQUENCE (``seq_lens`` (B,)) — the
whole point of continuous batching is that batch slots sit at unrelated
depths. Idle slots carry ``seq_len == 0`` and a null-page block table: their
write lands in the reserved page and their attention output is fully masked.

Token selection is greedy by default; ``temperature > 0`` switches the step
to temperature / top-k sampling with PER-SEQUENCE RNG keys threaded through
the jitted step (the key array is an extra step argument, so one compiled
program serves every step and re-seeding a sequence is just handing it a new
key row). Greedy steps keep the original 5-argument signature byte-for-byte.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.kernels import paged_decode
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.model import dequant_tree, embed_tokens

__all__ = ["make_paged_decode_step", "paged_attention_block", "sample_logits",
           "sample_step_keys"]


def sample_step_keys(key, batch: int):
    """(B, 2) uint32 per-sequence keys for one sampling step."""
    return jax.random.split(key, batch)


def sample_logits(logits, keys, *, temperature: float, top_k: int = 0):
    """Per-sequence temperature / top-k sampling.

    logits (B, V); keys (B, 2) uint32 (one key row per sequence, e.g. from
    ``sample_step_keys``). ``top_k > 0`` restricts sampling to the k highest
    logits; ``temperature <= 0`` degenerates to greedy argmax. Returns (B,)
    int32 — deterministic in (logits, keys).
    """
    logits = logits.astype(jnp.float32)
    if top_k and top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def _write_token(pool, phys, slot, val):
    """pool (N, psz, ...) <- val (B, ...) at (phys[b], slot[b]) per slot b."""
    return pool.at[phys, slot].set(val.astype(pool.dtype))


def paged_attention_block(p, cfg: ModelConfig, x, pools, block_tables,
                          seq_lens, *, use_pallas: bool = True):
    """Attention sublayer over the paged cache (one layer's pool slices).

    x: (B, 1, D) normed input; pools: {"k"/"v": (N, psz, Hkv, hd)[, scales]}.
    Returns (attn_out (B, 1, D), updated pools).
    """
    positions = seq_lens[:, None]                       # (B, 1) write position
    q, k, v = L.attn_qkv(p, cfg, x, positions)
    B = q.shape[0]
    psz = pools["k"].shape[1]
    phys = jnp.take_along_axis(block_tables, (seq_lens // psz)[:, None],
                               axis=1)[:, 0]            # (B,) physical page
    slot = seq_lens % psz
    new = dict(pools)
    if "k_scale" in pools:  # int8 pool: same convention as the dense cache
        kq, vq, ks, vs = L.quantize_kv(k, v)
        new["k"] = _write_token(pools["k"], phys, slot, kq[:, 0])
        new["v"] = _write_token(pools["v"], phys, slot, vq[:, 0])
        new["k_scale"] = _write_token(pools["k_scale"], phys, slot, ks[:, 0])
        new["v_scale"] = _write_token(pools["v_scale"], phys, slot, vs[:, 0])
    else:
        new["k"] = _write_token(pools["k"], phys, slot, k[:, 0])
        new["v"] = _write_token(pools["v"], phys, slot, v[:, 0])
    out = paged_decode(q[:, 0], new["k"], new["v"], block_tables, seq_lens + 1,
                       new.get("k_scale"), new.get("v_scale"),
                       use_pallas=use_pallas)
    return L.attn_out(p, out[:, None].astype(q.dtype), cfg), new


def make_paged_decode_step(cfg: ModelConfig, *, use_pallas: bool = True,
                           temperature: float = 0.0, top_k: int = 0):
    """(params_q, tokens (B,1), pools, block_tables (B,P), seq_lens (B,))
    -> (next_token (B,1) int32, updated pools).

    ``pools`` leaves carry a leading n_layers axis and are scanned alongside
    the stacked layer params, exactly like the dense cache in
    ``model.decode_step``. Only attention-cache architectures page.

    With ``temperature > 0`` the returned step takes one extra trailing
    argument, ``sample_keys`` (B, 2) uint32 per-sequence keys, and samples
    through ``sample_logits`` (optionally top-k-restricted); the default
    greedy step keeps the original signature and argmax selection unchanged.
    """
    if cfg.block_pattern not in ("dense", "moe"):
        raise ValueError(f"paged decode requires attention blocks, "
                         f"got {cfg.block_pattern!r}")
    if cfg.is_enc_dec:
        raise ValueError("paged decode does not cover cross-attention caches")

    def logits_step(params_q, tokens, pools, block_tables, seq_lens):
        positions = seq_lens[:, None]
        h = embed_tokens(params_q, cfg, tokens, positions)

        def body(carry, xs):
            pl, pool_slice = xs
            pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
            a_in = L.apply_norm(carry, pl["ln1"], cfg.norm)
            a, new_pool = paged_attention_block(
                pl["attn"], cfg, a_in, pool_slice, block_tables, seq_lens,
                use_pallas=use_pallas)
            hh = carry + a
            m_in = L.apply_norm(hh, pl["ln2"], cfg.norm)
            if "moe" in pl:
                hh = hh + L.moe_ffn(pl["moe"], cfg, m_in)
            else:
                hh = hh + L.mlp(pl["mlp"], cfg, m_in)
            return hh, new_pool

        h, new_pools = jax.lax.scan(body, h, (params_q["blocks"], pools),
                                    unroll=cfg.unroll_layers)
        h = L.apply_norm(h, params_q["final_norm"], cfg.norm)
        head = (params_q["embed"]["tok"].T if cfg.tie_embeddings
                else params_q["lm_head"])
        if isinstance(head, QTensor):
            head = head.dequantize(h.dtype)
        logits = h @ head.astype(h.dtype)
        V = logits.shape[-1]
        if V > cfg.vocab_size:
            logits = jnp.where(jnp.arange(V) < cfg.vocab_size, logits, -jnp.inf)
        return logits, new_pools

    if temperature > 0.0:
        def sampled_step(params_q, tokens, pools, block_tables, seq_lens,
                         sample_keys):
            logits, new_pools = logits_step(params_q, tokens, pools,
                                            block_tables, seq_lens)
            next_tok = sample_logits(logits[:, -1], sample_keys,
                                     temperature=temperature, top_k=top_k)
            return next_tok[:, None], new_pools
        return sampled_step

    def step(params_q, tokens, pools, block_tables, seq_lens):
        logits, new_pools = logits_step(params_q, tokens, pools, block_tables,
                                        seq_lens)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_pools

    return step
