"""Pluggable admission + eviction policy for the continuous batcher.

Until the prefix-caching refactor the policy layer was hardwired into
``ContinuousBatcher``: FIFO admission (queue head or nothing) and newest-first
recompute eviction (``_evict_newest``). Both assumed a page has exactly one
owner — with refcounted shared pages the cheap-to-evict victim is no longer
simply the newest, and multi-tenant serving needs admission control that FIFO
cannot express. The batcher now delegates every policy decision to a
``Scheduler``:

  pick_admit    which queued request (index into ``batcher.queue``) to admit
                next, or None to admit nothing this round
  pick_victim   which live slot index to preempt when the pool is exhausted
  admissible    whether a request may take ``n_pages`` more pages right now
                (per-tenant quota enforcement; also gates duplicate-admit
                aliasing, which allocates almost nothing but still holds
                references)

``FIFOScheduler`` reproduces the legacy behaviour decision-for-decision (the
batcher's pre-refactor tests pin this), so it is the default.

``SLOScheduler`` is the production policy:

  admission   highest ``PagedRequest.priority`` first; FIFO (arrival order)
              within a priority class, so equal-priority tenants cannot
              starve each other. A request whose tenant is at its page quota
              is skipped — a later, under-quota request may admit past it.
  eviction    lowest priority first; among equals, the slot with the LEAST
              progress toward completion (fewest generated tokens — the
              cheapest SLO damage), and ties broken by RE-ADMIT COST: pages
              shared with the prefix cache or another sequence survive the
              victim's release and will be re-aliased on re-admit, so a
              victim holding mostly shared pages loses almost nothing.
  quota       ``tenant_quota`` bounds the pages a tenant's live slots may
              hold simultaneously (aliased pages count against every
              holder); ``quotas`` overrides the bound per tenant.
"""
from __future__ import annotations

from typing import Dict, Optional

__all__ = ["Scheduler", "FIFOScheduler", "SLOScheduler", "make_scheduler"]


class Scheduler:
    """Policy interface; see module docstring. Methods receive the batcher
    itself — policies read ``batcher.queue`` / ``batcher.slots`` / the
    allocator, and must not mutate them."""

    def pick_admit(self, batcher) -> Optional[int]:
        raise NotImplementedError

    def pick_victim(self, batcher) -> Optional[int]:
        raise NotImplementedError

    def admissible(self, batcher, req, n_pages: int) -> bool:
        return True


class FIFOScheduler(Scheduler):
    """The legacy hardwired policy: admit the queue head, evict the newest
    admission (max ticket). Never evicts the only runner — recompute
    preemption of the sole live sequence makes no forward progress."""

    def pick_admit(self, batcher) -> Optional[int]:
        return 0 if batcher.queue else None

    def pick_victim(self, batcher) -> Optional[int]:
        live = [(i, s) for i, s in enumerate(batcher.slots) if s is not None]
        if len(live) <= 1:
            return None
        return max(live, key=lambda t: t[1].ticket)[0]


class SLOScheduler(Scheduler):
    def __init__(self, tenant_quota: Optional[int] = None,
                 quotas: Optional[Dict[str, int]] = None):
        self.tenant_quota = tenant_quota
        self.quotas = dict(quotas or {})

    # -- quota -------------------------------------------------------------

    def _quota_of(self, tenant: str) -> Optional[int]:
        return self.quotas.get(tenant, self.tenant_quota)

    def _held_pages(self, batcher, tenant: str) -> int:
        # a shared page counts against every holder: quotas bound references
        # (what a tenant can pin), not exclusive bytes — otherwise one tenant
        # could pin the whole pool through the prefix cache for free
        return sum(len(s.page_ids) for s in batcher.slots
                   if s is not None and s.req.tenant == tenant)

    def admissible(self, batcher, req, n_pages: int) -> bool:
        quota = self._quota_of(req.tenant)
        if quota is None:
            return True
        return self._held_pages(batcher, req.tenant) + n_pages <= quota

    # -- admission ---------------------------------------------------------

    def pick_admit(self, batcher) -> Optional[int]:
        best = None
        for qi, req in enumerate(batcher.queue):
            need = batcher.pages_needed(req)
            if not self.admissible(batcher, req, need):
                continue
            key = (-req.priority, req.arrival)
            if best is None or key < best[0]:
                best = (key, qi)
        return None if best is None else best[1]

    # -- eviction ----------------------------------------------------------

    def pick_victim(self, batcher) -> Optional[int]:
        live = [(i, s) for i, s in enumerate(batcher.slots) if s is not None]
        if len(live) <= 1:
            return None

        alloc = batcher.cache.allocator
        psz = batcher.cache.page_size

        def score(item):
            i, s = item
            # pages with other owners (prefix cache or a co-owning sequence)
            # survive this slot's release: the re-admit re-aliases them, so
            # only exclusively-owned pages are genuine recompute cost
            exclusive = sum(1 for p in s.page_ids if alloc.refcount(p) == 1)
            progress = len(s.req.out) / max(s.req.max_new, 1)
            return (s.req.priority, progress, exclusive * psz, -s.ticket)

        return min(live, key=score)[0]


def make_scheduler(name: str, tenant_quota: Optional[int] = None,
                   quotas: Optional[Dict[str, int]] = None) -> Scheduler:
    """Flag-friendly factory: ``fifo`` (legacy-identical) or ``slo``."""
    if name == "fifo":
        return FIFOScheduler()
    if name == "slo":
        return SLOScheduler(tenant_quota=tenant_quota, quotas=quotas)
    raise ValueError(f"unknown scheduler {name!r} (want 'fifo' or 'slo')")
