"""Paged KV-cache storage: a global page pool + per-sequence block tables.

Instead of one contiguous ``(B, max_len, ...)`` cache buffer per batch slot
(whose memory is ``max_len``-bound regardless of actual lengths), the cache is
a pool of fixed-size pages shared by every sequence:

    k_pages / v_pages : (n_layers, n_pages, page_size, n_kv_heads, head_dim)

A sequence of length ``s`` holds exactly ``ceil(s / page_size)`` page ids (the
same ids index every layer's pool), so pool memory tracks the LIVE token count
— the memory term BiLLM (2402.04291) shows dominates ultra-low-bit serving.
Page ids are handed out by a free-list ``PageAllocator`` and returned when a
sequence finishes (or is preempted), which is what lets the continuous
batcher keep admitting new requests between decode steps.

Physical page 0 is reserved as the *null page*: idle batch slots point their
block tables at it, so the jitted decode step can scatter-write
unconditionally without corrupting a live sequence.

With ``cfg.kv_cache_dtype == "int8"`` pages store int8 codes plus per-(slot,
head) absmax scales — the same quantized layout as the contiguous cache in
``repro.models.layers`` (scales per group of ``head_dim`` values, matching the
group-quant scales convention of one scale per contiguous value group).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["PageAllocator", "PagedKVCache", "NULL_PAGE"]

NULL_PAGE = 0


class PageAllocator:
    """LIFO free-list over page ids [reserved, n_pages).

    ``alloc`` is all-or-nothing (a partial grant would deadlock the batcher:
    a sequence cannot attend over half its prompt), and ``free`` rejects
    double-frees — an id returned twice means two sequences believe they own
    the same page, which silently corrupts attention output.
    """

    def __init__(self, n_pages: int, reserved: int = 1):
        if n_pages <= reserved:
            raise ValueError(f"need more than {reserved} pages, got {n_pages}")
        self.n_pages = n_pages
        self.reserved = reserved
        self._free: List[int] = list(range(n_pages - 1, reserved - 1, -1))
        self._live = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n page ids, or None (and no side effects) if fewer than n are free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._live.update(ids)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for i in ids:
            if i not in self._live:
                raise ValueError(f"double free / foreign page id {i}")
            self._live.discard(i)
            self._free.append(i)


class PagedKVCache:
    """Device page pools for every layer plus the page allocator.

    The pools are plain jnp arrays handed in and out of the jitted decode
    step (functional updates); this object owns their *identity* between
    steps and the host-side allocator state.
    """

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 max_pages_per_seq: int):
        if cfg.block_pattern not in ("dense", "moe"):
            raise ValueError(
                f"paged KV cache requires an attention cache; "
                f"block_pattern={cfg.block_pattern!r} keeps O(1) state")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages_per_seq = max_pages_per_seq
        self.allocator = PageAllocator(n_pages)
        L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        self.quantized = cfg.kv_cache_dtype == "int8"
        dt = jnp.dtype(cfg.compute_dtype)
        kv_dt = jnp.int8 if self.quantized else dt
        self.pools = {
            "k": jnp.zeros((L, n_pages, page_size, Hkv, hd), kv_dt),
            "v": jnp.zeros((L, n_pages, page_size, Hkv, hd), kv_dt),
        }
        if self.quantized:
            self.pools["k_scale"] = jnp.zeros((L, n_pages, page_size, Hkv), dt)
            self.pools["v_scale"] = jnp.zeros((L, n_pages, page_size, Hkv), dt)

    # -- geometry ----------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def pool_bytes(self) -> int:
        return sum(int(a.size * a.dtype.itemsize) for a in self.pools.values())

    def dense_equiv_bytes(self, batch: int, max_len: int) -> int:
        """What a contiguous (B, max_len) cache would cost at the same dtype."""
        per_tok = sum(
            int(np.prod(a.shape[3:]) * a.dtype.itemsize) * a.shape[0]
            for a in self.pools.values())
        return batch * max_len * per_tok

    # -- block tables ------------------------------------------------------

    def block_table_row(self, page_ids: Sequence[int]) -> np.ndarray:
        """(max_pages_per_seq,) int32 row, padded with the null page."""
        if len(page_ids) > self.max_pages_per_seq:
            raise ValueError(
                f"sequence needs {len(page_ids)} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        row = np.full((self.max_pages_per_seq,), NULL_PAGE, np.int32)
        row[: len(page_ids)] = page_ids
        return row

    def gather_tokens(self, page_ids: Sequence[int], length: int) -> dict:
        """Read the first ``length`` token rows of a sequence back out of the
        pool: {key: (L, length, ...)} in token order. Test/debug helper — the
        serving path never materialises this contiguous view."""
        ids = jnp.asarray(page_ids, jnp.int32)
        out = {}
        for key, pool in self.pools.items():
            rows = pool[:, ids]                          # (L, n, psz, ...)
            rows = rows.reshape((rows.shape[0], -1) + rows.shape[3:])
            out[key] = rows[:, :length]
        return out

    # -- prefill write (legacy contiguous path) ----------------------------

    def write_prefill(self, page_ids: Sequence[int], cache: dict,
                      length: int) -> None:
        """Scatter a freshly prefilled contiguous cache into the pool.

        ``cache`` is ``model.prefill``'s per-layer cache for ONE sequence
        (leaves (L, 1, S_pad, ...)) with ``S_pad >= len(page_ids) *
        page_size`` covering the ``length``-token prompt. Rows past
        ``length`` inside the last page carry garbage — masked at read time
        by the per-sequence length.

        Since serving v2 the batcher admits through the CHUNKED paged
        prefill (``serving/prefill.py``) and never calls this; it remains as
        the reference path the equivalence tests compare against.
        """
        n = len(page_ids)
        need = self.pages_for(length)
        if n < need:
            raise ValueError(f"{n} pages cannot hold {length} tokens")
        ids = jnp.asarray(page_ids, jnp.int32)
        for key in self.pools:
            src = cache[key][:, 0]                       # (L, S_pad, ...)
            if src.shape[1] < n * self.page_size:
                raise ValueError(
                    f"prefill cache depth {src.shape[1]} < {n} pages")
            src = src[:, : n * self.page_size]
            src = src.reshape((src.shape[0], n, self.page_size) + src.shape[2:])
            self.pools[key] = self.pools[key].at[:, ids].set(
                src.astype(self.pools[key].dtype))
