"""Paged KV-cache storage: a global page pool + per-sequence block tables.

Instead of one contiguous ``(B, max_len, ...)`` cache buffer per batch slot
(whose memory is ``max_len``-bound regardless of actual lengths), the cache is
a pool of fixed-size pages shared by every sequence:

    k_pages / v_pages : (n_layers, n_pages, page_size, n_kv_heads, head_dim)

A sequence of length ``s`` holds exactly ``ceil(s / page_size)`` page ids (the
same ids index every layer's pool), so pool memory tracks the LIVE token count
— the memory term BiLLM (2402.04291) shows dominates ultra-low-bit serving.
Page ids are handed out by a free-list ``PageAllocator`` and returned when a
sequence finishes (or is preempted), which is what lets the continuous
batcher keep admitting new requests between decode steps.

Physical page 0 is reserved as the *null page*: idle batch slots point their
block tables at it, so the jitted decode step can scatter-write
unconditionally without corrupting a live sequence.

Since the prefix-caching refactor a page is a REFCOUNTED object rather than
the property of one sequence: ``retain`` adds an owner, ``release`` drops one
and returns the page to the free list only at zero, and ``free`` keeps its
historical name as an alias of ``release`` (including the double-free /
foreign-id guard). Two structures share pages:

  - ``PrefixCache``: a content-addressed index mapping the chained hash of
    each FULL page of prompt tokens to the physical page holding its K/V.
    The cache itself holds one reference per indexed page, so cached runs
    survive their producing sequence; unreferenced entries are retired in
    LRU order when the pool runs dry.
  - duplicate-admit aliasing: a queued request whose content is identical to
    a just-admitted one joins the batch by retaining the admitted slot's
    pages outright (zero prefill); the first decode write into a page still
    shared with another owner triggers a copy-on-write fork
    (``PagedKVCache.fork_page``) so owners never mutate shared state.

With ``cfg.kv_cache_dtype == "int8"`` pages store int8 codes plus per-(slot,
head) absmax scales — the same quantized layout as the contiguous cache in
``repro.models.layers`` (scales per group of ``head_dim`` values, matching the
group-quant scales convention of one scale per contiguous value group).
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["PageAllocator", "PagedKVCache", "PrefixCache", "NULL_PAGE",
           "chain_keys"]

NULL_PAGE = 0


@jax.jit
def _copy_page(pools, src, dst):
    """{leaf: (L, N, ...)} with row ``dst`` <- row ``src`` on every leaf."""
    return {k: v.at[:, dst].set(v[:, src]) for k, v in pools.items()}


class PageAllocator:
    """Refcounting LIFO free-list over page ids [reserved, n_pages).

    ``alloc`` is all-or-nothing (a partial grant would deadlock the batcher:
    a sequence cannot attend over half its prompt) and hands pages out with
    refcount 1. ``retain`` adds an owner; ``release`` (alias ``free``) drops
    one and returns the page to the free list only when the count reaches
    zero. Releasing a page that is not live raises — an id returned twice
    means two owners believe they dropped the same reference, which silently
    corrupts attention output once the page is re-issued.
    """

    def __init__(self, n_pages: int, reserved: int = 1):
        if n_pages <= reserved:
            raise ValueError(f"need more than {reserved} pages, got {n_pages}")
        self.n_pages = n_pages
        self.reserved = reserved
        self._free: List[int] = list(range(n_pages - 1, reserved - 1, -1))
        self._ref: Dict[int, int] = {}       # live page id -> owner count

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._ref)

    def refcount(self, i: int) -> int:
        """Current owner count of page ``i`` (0 if the page is free)."""
        return self._ref.get(i, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n page ids at refcount 1, or None (no side effects) if fewer free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
        return ids

    def retain(self, ids: Sequence[int]) -> None:
        """Add one owner to each live page; retaining a free page raises."""
        for i in ids:
            if i not in self._ref:
                raise ValueError(f"retain of free / foreign page id {i}")
        for i in ids:
            self._ref[i] += 1

    def release(self, ids: Sequence[int]) -> List[int]:
        """Drop one owner per id; returns the ids that actually went free."""
        freed = []
        for i in ids:
            n = self._ref.get(i, 0)
            if n <= 0:
                raise ValueError(f"double free / foreign page id {i}")
            if n == 1:
                del self._ref[i]
                self._free.append(i)
                freed.append(i)
            else:
                self._ref[i] = n - 1
        return freed

    # historical name: single-owner callers (and the allocator tests) treat
    # "free" as "drop my reference", which is exactly what release does
    free = release


def chain_keys(tokens: np.ndarray, page_size: int) -> List[bytes]:
    """Content-addressed keys for every FULL page of ``tokens``.

    ``keys[i]`` commits to pages 0..i (the hash chains the previous key), so
    equal keys mean the whole prefix up to and including page ``i`` is
    token-identical — a page's K/V depends on every earlier position, so the
    prefix cache must never match on page content alone.
    """
    keys = []
    prev = b"paged-prefix-v1"
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    for i in range(len(toks) // page_size):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(toks[i * page_size: (i + 1) * page_size].tobytes())
        prev = h.digest()
        keys.append(prev)
    return keys


class PrefixCache:
    """Content-addressed prefix index over the page pool.

    Maps ``chain_keys`` entries (the chained hash of a full-page prompt run)
    to the physical page holding that run's K/V. The cache holds ONE
    reference on every indexed page, so cached runs outlive the sequence
    that produced them; ``evict_lru`` retires entries whose page has no other
    owner (refcount 1) in least-recently-matched order when the allocator
    runs dry, and ``clear`` drops every cache reference (pages still owned
    by live slots survive — they just stop being findable).
    """

    def __init__(self, allocator: PageAllocator,
                 max_entries: Optional[int] = None):
        self.allocator = allocator
        self.max_entries = max_entries
        self._runs: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._runs)

    def lookup(self, keys: Sequence[bytes]) -> List[int]:
        """Longest indexed prefix run of ``keys`` -> page ids, RETAINED for
        the caller (one new reference per returned page)."""
        run: List[int] = []
        for k in keys:
            pid = self._runs.get(k)
            if pid is None:
                self.misses += 1
                break
            self._runs.move_to_end(k)
            run.append(pid)
        self.hits += len(run)
        self.allocator.retain(run)
        return run

    def insert(self, key: bytes, page_id: int) -> bool:
        """Index ``page_id`` under ``key`` (cache takes its own reference).
        Returns False (no reference taken) if the key is already present."""
        if key in self._runs:
            self._runs.move_to_end(key)
            return False
        self.allocator.retain([page_id])
        self._runs[key] = page_id
        if self.max_entries is not None and len(self._runs) > self.max_entries:
            self.evict_lru(len(self._runs) - self.max_entries)
        return True

    def evict_lru(self, n_pages: int) -> int:
        """Retire up to ``n_pages`` unreferenced entries (LRU first).

        Only entries whose page the cache is the SOLE owner of (refcount 1)
        are retired — pages still aliased into live block tables must keep
        their index entry, releasing them would not free memory anyway.
        """
        freed = 0
        if n_pages <= 0:
            return 0
        for key in list(self._runs):
            pid = self._runs[key]
            if self.allocator.refcount(pid) == 1:
                del self._runs[key]
                self.allocator.release([pid])
                freed += 1
                if freed >= n_pages:
                    break
        return freed

    def clear(self) -> None:
        """Drop every cache reference (end-of-run drain)."""
        for pid in self._runs.values():
            self.allocator.release([pid])
        self._runs.clear()


class PagedKVCache:
    """Device page pools for every layer plus the page allocator.

    The pools are plain jnp arrays handed in and out of the jitted decode
    step (functional updates); this object owns their *identity* between
    steps and the host-side allocator state.
    """

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 max_pages_per_seq: int):
        if cfg.block_pattern not in ("dense", "moe"):
            raise ValueError(
                f"paged KV cache requires an attention cache; "
                f"block_pattern={cfg.block_pattern!r} keeps O(1) state")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages_per_seq = max_pages_per_seq
        self.allocator = PageAllocator(n_pages)
        L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        self.quantized = cfg.kv_cache_dtype == "int8"
        dt = jnp.dtype(cfg.compute_dtype)
        kv_dt = jnp.int8 if self.quantized else dt
        self.pools = {
            "k": jnp.zeros((L, n_pages, page_size, Hkv, hd), kv_dt),
            "v": jnp.zeros((L, n_pages, page_size, Hkv, hd), kv_dt),
        }
        if self.quantized:
            self.pools["k_scale"] = jnp.zeros((L, n_pages, page_size, Hkv), dt)
            self.pools["v_scale"] = jnp.zeros((L, n_pages, page_size, Hkv), dt)

    # -- geometry ----------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def pool_bytes(self) -> int:
        return sum(int(a.size * a.dtype.itemsize) for a in self.pools.values())

    def dense_equiv_bytes(self, batch: int, max_len: int) -> int:
        """What a contiguous (B, max_len) cache would cost at the same dtype."""
        per_tok = sum(
            int(np.prod(a.shape[3:]) * a.dtype.itemsize) * a.shape[0]
            for a in self.pools.values())
        return batch * max_len * per_tok

    # -- block tables ------------------------------------------------------

    def block_table_row(self, page_ids: Sequence[int]) -> np.ndarray:
        """(max_pages_per_seq,) int32 row, padded with the null page."""
        if len(page_ids) > self.max_pages_per_seq:
            raise ValueError(
                f"sequence needs {len(page_ids)} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        row = np.full((self.max_pages_per_seq,), NULL_PAGE, np.int32)
        row[: len(page_ids)] = page_ids
        return row

    def gather_tokens(self, page_ids: Sequence[int], length: int) -> dict:
        """Read the first ``length`` token rows of a sequence back out of the
        pool: {key: (L, length, ...)} in token order. Test/debug helper — the
        serving path never materialises this contiguous view."""
        ids = jnp.asarray(page_ids, jnp.int32)
        out = {}
        for key, pool in self.pools.items():
            rows = pool[:, ids]                          # (L, n, psz, ...)
            rows = rows.reshape((rows.shape[0], -1) + rows.shape[3:])
            out[key] = rows[:, :length]
        return out

    # -- copy-on-write fork ------------------------------------------------

    def fork_page(self, src: int, dst: int) -> None:
        """Copy page ``src``'s rows (every layer, every pool leaf) into
        ``dst`` — the copy-on-write fork run by the batcher before a decode
        write would mutate a page that still has other owners. One jitted
        program regardless of page ids (ids are traced scalars)."""
        self.pools = _copy_page(self.pools, jnp.int32(src), jnp.int32(dst))

    # -- prefill write (legacy contiguous path) ----------------------------

    def write_prefill(self, page_ids: Sequence[int], cache: dict,
                      length: int) -> None:
        """Scatter a freshly prefilled contiguous cache into the pool.

        ``cache`` is ``model.prefill``'s per-layer cache for ONE sequence
        (leaves (L, 1, S_pad, ...)) with ``S_pad >= len(page_ids) *
        page_size`` covering the ``length``-token prompt. Rows past
        ``length`` inside the last page carry garbage — masked at read time
        by the per-sequence length.

        Since serving v2 the batcher admits through the CHUNKED paged
        prefill (``serving/prefill.py``) and never calls this; it remains as
        the reference path the equivalence tests compare against.
        """
        n = len(page_ids)
        need = self.pages_for(length)
        if n < need:
            raise ValueError(f"{n} pages cannot hold {length} tokens")
        ids = jnp.asarray(page_ids, jnp.int32)
        for key in self.pools:
            src = cache[key][:, 0]                       # (L, S_pad, ...)
            if src.shape[1] < n * self.page_size:
                raise ValueError(
                    f"prefill cache depth {src.shape[1]} < {n} pages")
            src = src[:, : n * self.page_size]
            src = src.reshape((src.shape[0], n, self.page_size) + src.shape[2:])
            self.pools[key] = self.pools[key].at[:, ids].set(
                src.astype(self.pools[key].dtype))
