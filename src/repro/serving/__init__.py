"""Paged KV-cache serving subsystem (continuous batching).

- ``paged_cache``: fixed-size page pool, free-list allocator, block tables
- ``prefill``: chunked paged prefill (prompt K/V written straight into pages)
- ``decode``: jit-able paged decode step (scatter-write + paged attention,
  per-request sampling params threaded as (B,) arrays)
- ``batcher``: admit / evict / reclaim scheduler between decode steps

The Pallas kernels behind the attention read live in
``repro.kernels.paged_decode`` (including the fused-GQA variant that reads
each KV head's page once for all of its query heads); ``launch/serve.py``
wraps this package as the serving driver.
"""
from repro.serving.paged_cache import PageAllocator, PagedKVCache, NULL_PAGE
from repro.serving.decode import (make_paged_decode_step,
                                  paged_attention_block, request_key,
                                  sample_logits, sample_logits_per_seq,
                                  sample_step_keys)
from repro.serving.prefill import (make_paged_prefill_step,
                                   paged_prefill_attention)
from repro.serving.batcher import ContinuousBatcher, PagedRequest

__all__ = ["PageAllocator", "PagedKVCache", "NULL_PAGE",
           "make_paged_decode_step", "paged_attention_block",
           "make_paged_prefill_step", "paged_prefill_attention",
           "request_key", "sample_logits", "sample_logits_per_seq",
           "sample_step_keys", "ContinuousBatcher", "PagedRequest"]
