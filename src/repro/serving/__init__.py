"""Paged KV-cache serving subsystem (continuous batching).

- ``paged_cache``: fixed-size page pool, free-list allocator, block tables
- ``prefill``: chunked paged prefill (prompt K/V written straight into pages)
- ``decode``: jit-able paged decode step (scatter-write + paged attention,
  per-request sampling params threaded as (B,) arrays)
- ``batcher``: admit / evict / reclaim loop between decode steps, with
  refcounted page sharing (prefix-cache aliasing, duplicate-admit twins,
  decode-time copy-on-write forks)
- ``scheduler``: pluggable admission/eviction policy (FIFO legacy default;
  SLO priority + fairness + per-tenant page quotas)

The Pallas kernels behind the attention read live in
``repro.kernels.paged_decode`` (including the fused-GQA variant that reads
each KV head's page once for all of its query heads); ``launch/serve.py``
wraps this package as the serving driver.
"""
from repro.serving.paged_cache import (PageAllocator, PagedKVCache,
                                       PrefixCache, NULL_PAGE, chain_keys)
from repro.serving.decode import (make_paged_decode_step,
                                  paged_attention_block, request_key,
                                  sample_logits, sample_logits_per_seq,
                                  sample_step_keys)
from repro.serving.prefill import (make_paged_prefill_step,
                                   paged_prefill_attention,
                                   run_prefill_chunks)
from repro.serving.batcher import ContinuousBatcher, PagedRequest
from repro.serving.scheduler import (FIFOScheduler, Scheduler, SLOScheduler,
                                     make_scheduler)
from repro.serving.trace import build_trace

__all__ = ["PageAllocator", "PagedKVCache", "PrefixCache", "NULL_PAGE",
           "chain_keys", "make_paged_decode_step", "paged_attention_block",
           "make_paged_prefill_step", "paged_prefill_attention",
           "run_prefill_chunks", "request_key", "sample_logits",
           "sample_logits_per_seq", "sample_step_keys", "ContinuousBatcher",
           "PagedRequest", "Scheduler", "FIFOScheduler", "SLOScheduler",
           "make_scheduler", "build_trace"]
