"""Paged KV-cache serving subsystem (continuous batching).

- ``paged_cache``: fixed-size page pool, free-list allocator, block tables
- ``decode``: jit-able paged decode step (scatter-write + paged attention)
- ``batcher``: admit / evict / reclaim scheduler between decode steps

The Pallas kernel behind the attention read lives in
``repro.kernels.paged_decode``; ``launch/serve.py`` wraps this package as the
serving driver.
"""
from repro.serving.paged_cache import PageAllocator, PagedKVCache, NULL_PAGE
from repro.serving.decode import (make_paged_decode_step,
                                  paged_attention_block, sample_logits,
                                  sample_step_keys)
from repro.serving.batcher import ContinuousBatcher, PagedRequest

__all__ = ["PageAllocator", "PagedKVCache", "NULL_PAGE",
           "make_paged_decode_step", "paged_attention_block",
           "sample_logits", "sample_step_keys",
           "ContinuousBatcher", "PagedRequest"]
