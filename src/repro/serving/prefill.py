"""Chunked paged prefill: prompt K/V written straight into allocated pages.

The v1 admit path ran a CONTIGUOUS prefill over the whole right-padded prompt
(one ``(1, s_pad)`` cache buffer per admit) and then scatter-copied every
layer's K/V into the page pool (``PagedKVCache.write_prefill``). That is two
full passes over the prompt's KV bytes, one jit shape per padded prompt
length, and a transient contiguous allocation that defeats the point of
paging.

This module prefills *in page-aligned chunks*:

  - the prompt is split into chunks of ``chunk_pages * page_size`` tokens
    (the tail chunk padded up to a page multiple), so the jitted step sees at
    most ``chunk_pages`` distinct shapes TOTAL — not one per prompt length;
  - each chunk's K/V is written DIRECTLY into the sequence's allocated pages
    (a ``(C // page_size)``-page scatter inside the jitted step — no
    contiguous ``(1, s_pad)`` KV buffer ever exists);
  - chunk attention runs over the page pool itself through an online-softmax
    scan across the block table (``paged_prefill_attention``): one page is
    gathered per scan step, causally masked at absolute positions, so
    chunk c attends over chunks 0..c-1's pages plus its own freshly written
    pages without materialising a contiguous cache.

The jnp scan is the portable fallback the ISSUE allows; the page-gather
structure mirrors ``kernels/paged_decode.py``'s grid (one page per step,
online (m, l, acc) carry) so a Pallas lowering can swap in per page-block
without changing the batcher contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.model import embed_tokens, lm_head_logits
from repro.serving.decode import paged_block_body

__all__ = ["paged_prefill_attention", "make_paged_prefill_step",
           "run_prefill_chunks"]

NEG = -1e30


def paged_prefill_attention(q, pools, block_tables, offset):
    """Causal attention of a prefill chunk over the page pool.

    q: (B, C, H, Dh) chunk queries at absolute positions ``offset + i``;
    pools: one layer's slices {"k"/"v": (N, psz, Hkv, Dh)[, "k_scale"/...]};
    block_tables: (B, P) physical page ids; offset: scalar int32 (page
    aligned). Keys live in the pool ONLY — each scan step gathers a single
    (B, psz, Hkv, Dh) page, keeps the online-softmax (m, l, acc) carry, and
    masks by ``key_pos <= query_pos`` so dead/null/garbage page slots never
    contribute. Returns (B, C, H, Dh) f32.
    """
    B, C, H, Dh = q.shape
    kp, vp = pools["k"], pools["v"]
    psz, Hkv = kp.shape[1], kp.shape[2]
    rep = H // Hkv
    P = block_tables.shape[1]
    ks, vs = pools.get("k_scale"), pools.get("v_scale")

    qf = q.astype(jnp.float32) * Dh ** -0.5
    q_pos = offset + jnp.arange(C)                       # (C,) absolute

    def body(p, carry):
        m, l, acc = carry
        pg = block_tables[:, p]                          # (B,) physical page
        kb = kp[pg].astype(jnp.float32)                  # (B, psz, Hkv, Dh)
        vb = vp[pg].astype(jnp.float32)
        if ks is not None:
            kb = kb * ks[pg][..., None].astype(jnp.float32)
            vb = vb * vs[pg][..., None].astype(jnp.float32)
        if rep > 1:
            kb = jnp.repeat(kb, rep, axis=2)
            vb = jnp.repeat(vb, rep, axis=2)
        s = jnp.einsum("bchd,bkhd->bhck", qf, kb)        # (B, H, C, psz)
        k_pos = p * psz + jnp.arange(psz)
        mask = k_pos[None, :] <= q_pos[:, None]          # (C, psz) causal
        s = jnp.where(mask[None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        prob = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        acc = acc * corr[..., None] + jnp.einsum("bhck,bkhd->bhcd", prob, vb)
        l = l * corr + jnp.sum(prob, axis=-1)
        return (m_new, l, acc)

    m0 = jnp.full((B, H, C), NEG, jnp.float32)
    l0 = jnp.zeros((B, H, C), jnp.float32)
    a0 = jnp.zeros((B, H, C, Dh), jnp.float32)
    # causality bounds the reachable keys at offset + C, so only the first
    # ceil((offset + C) / psz) table entries can contribute — a fori_loop
    # with that (traced) bound keeps admit cost O(live pages), not
    # O(max_pages_per_seq), per chunk (C and offset are page multiples, so
    # the division is exact; the bound is clamped to the table width).
    n_reach = jnp.minimum((offset + C) // psz, P)
    m, l, acc = jax.lax.fori_loop(0, n_reach, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3)                     # (B, C, H, Dh)


def _write_chunk(pool, page_ids, val):
    """pool (N, psz, ...) <- val (B=1, C, ...) across the chunk's pages."""
    n = page_ids.shape[0]
    psz = pool.shape[1]
    src = val[0].reshape((n, psz) + val.shape[2:])
    return pool.at[page_ids].set(src.astype(pool.dtype))


def paged_prefill_block(p, cfg: ModelConfig, x, pools, block_tables, offset):
    """One layer's attention sublayer for a prefill chunk (write + attend).

    x: (1, C, D) normed input, C a page multiple, ``offset`` page-aligned.
    Writes the chunk's K/V into pages ``block_tables[0, offset//psz : ... +
    C//psz]`` then attends over the pool. Returns (attn_out, new pools).
    """
    B, C, _ = x.shape
    psz = pools["k"].shape[1]
    positions = offset + jnp.arange(C)[None]             # (1, C)
    q, k, v = L.attn_qkv(p, cfg, x, positions)
    ids = jax.lax.dynamic_slice(block_tables[0], (offset // psz,),
                                (C // psz,))             # this chunk's pages
    new = dict(pools)
    if "k_scale" in pools:
        kq, vq, kscale, vscale = L.quantize_kv(k, v)
        new["k"] = _write_chunk(pools["k"], ids, kq)
        new["v"] = _write_chunk(pools["v"], ids, vq)
        new["k_scale"] = _write_chunk(pools["k_scale"], ids, kscale)
        new["v_scale"] = _write_chunk(pools["v_scale"], ids, vscale)
    else:
        new["k"] = _write_chunk(pools["k"], ids, k)
        new["v"] = _write_chunk(pools["v"], ids, v)
    out = paged_prefill_attention(q, new, block_tables, offset)
    return L.attn_out(p, out.astype(q.dtype), cfg), new


def run_prefill_chunks(chunk_fn, params_q, pools, full, block_table, *,
                       page_size: int, chunk_pages: int, start: int = 0):
    """Drive ``chunk_fn`` (a compiled ``make_paged_prefill_step``) over
    ``full[start:]`` in page-aligned chunks.

    ``start`` must be a ``page_size`` multiple strictly below ``len(full)`` —
    the admit path's prefix-cache hook: positions below ``start`` were
    aliased from already-populated pages and are skipped entirely (zero
    prefill for the cached run), so only the divergent tail is computed.
    Returns ``(last_logits_row, pools, n_chunks)`` where ``last_logits_row``
    is the (V,) logits of the final prompt position (the first-token input).
    """
    plen = len(full)
    if not 0 <= start < plen:
        raise ValueError(f"start={start} outside prompt of {plen} tokens")
    if start % page_size:
        raise ValueError(f"start={start} not page aligned (psz={page_size})")
    chunk_tokens = max(chunk_pages, 1) * page_size
    off, last_off = start, start
    logits = None
    n_chunks = 0
    while off < plen:
        n_tok = min(chunk_tokens, plen - off)
        c = -(-n_tok // page_size) * page_size  # pad tail to a page multiple
        toks = np.zeros((1, c), np.int32)
        toks[0, :n_tok] = full[off: off + n_tok]
        logits, pools = chunk_fn(params_q, jnp.asarray(toks), pools,
                                 block_table, jnp.int32(off))
        n_chunks += 1
        last_off, off = off, off + n_tok
    return logits[0, (plen - 1) - last_off], pools, n_chunks


def make_paged_prefill_step(cfg: ModelConfig):
    """(params_q, tokens (1, C), pools, block_tables (1, P), offset ())
    -> (logits (1, C, V) vocab-masked, updated pools).

    One prefill CHUNK: C must be a ``page_size`` multiple and ``offset`` a
    page-aligned scalar (traced — one compiled program per chunk length C,
    shared by every admit). The layer stack is scanned with the page pools as
    carried slices, exactly like ``make_paged_decode_step``; padded tail
    positions write garbage into the chunk's own allocated pages (masked at
    every later read by causality / per-sequence lengths).
    """
    if cfg.block_pattern not in ("dense", "moe"):
        raise ValueError(f"paged prefill requires attention blocks, "
                         f"got {cfg.block_pattern!r}")
    if cfg.is_enc_dec:
        raise ValueError("paged prefill does not cover cross-attention caches")

    def chunk_step(params_q, tokens, pools, block_tables, offset):
        C = tokens.shape[1]
        positions = offset + jnp.arange(C)
        h = embed_tokens(params_q, cfg, tokens, positions)

        def attn(p, x, pool_slice):
            return paged_prefill_block(p, cfg, x, pool_slice, block_tables,
                                       offset)

        def body(carry, xs):
            pl, pool_slice = xs
            return paged_block_body(pl, cfg, carry, pool_slice, attn)

        h, new_pools = jax.lax.scan(body, h, (params_q["blocks"], pools),
                                    unroll=cfg.unroll_layers)
        logits = lm_head_logits(params_q, cfg, h, mask_vocab=True)
        return logits, new_pools

    return chunk_step
