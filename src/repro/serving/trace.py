"""Many-tenant shared-prefix request traces (bench + acceptance tests).

The workload shape the prefix cache is built for: every request opens with
one SHARED system prompt, each tenant adds its own template on top, and only
a short user tail differs per request — so full-page prefix runs repeat both
across tenants (the system pages) and within a tenant (system + template
pages). A configurable slice of requests are exact duplicates of their
tenant's previous request (the dedup/COW path). The builder returns plain
kwargs dicts so both ``launch.serve.Request`` and ``serving.PagedRequest``
can be constructed from one trace without import cycles.
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["build_trace"]


def build_trace(vocab_size: int, *, n_tenants: int = 8, per_tenant: int = 3,
                dup_every: int = 4, page_size: int = 16, max_new: int = 8,
                sys_pages: int = 2, tpl_pages: int = 1,
                seed: int = 0) -> List[dict]:
    """A deterministic multi-tenant trace as a list of request kwargs.

    Layout per request: ``sys_pages`` pages shared by EVERY request,
    ``tpl_pages`` pages shared within the tenant, then a 4..(psz-2)-token
    random tail. Every ``dup_every``-th request (trace-wide) is instead an
    exact copy of its tenant's previous request — same prompt AND same
    ``max_new`` — so admission can dedup it outright. Requests interleave
    round-robin across tenants (the arrival order a multi-tenant frontend
    actually produces) with ``priority = tenant_index % 3``.
    """
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab_size,
                              size=sys_pages * page_size).astype(np.int32)
    tpl = {t: rng.integers(0, vocab_size,
                           size=tpl_pages * page_size).astype(np.int32)
           for t in range(n_tenants)}
    reqs: List[dict] = []
    prev_by_tenant: dict = {}
    for r in range(per_tenant):
        for t in range(n_tenants):
            i = len(reqs)
            if dup_every and i % dup_every == dup_every - 1 \
                    and t in prev_by_tenant:
                prev = prev_by_tenant[t]
                req = dict(prev, seed=i)    # own sample stream, same content
            else:
                tail = rng.integers(
                    0, vocab_size,
                    size=int(rng.integers(4, page_size - 1))).astype(np.int32)
                req = dict(prompt=np.concatenate([sys_prompt, tpl[t], tail]),
                           max_new=max_new, seed=i,
                           tenant=f"tenant{t}", priority=t % 3)
            prev_by_tenant[t] = req
            reqs.append(req)
    return reqs
