"""Continuous batching scheduler over the paged KV cache.

The toy server in ``launch/serve.py`` ran fixed batches to completion — a
batch of mixed-length requests waited for its longest member and its cache
slots were sized for ``max_len`` regardless of use. The batcher replaces that
with the production loop:

  admit     between decode steps, free batch slots are filled from the queue:
            the prompt is prefilled in PAGE-ALIGNED CHUNKS written straight
            into freshly allocated pages (``serving/prefill.py`` — no
            contiguous KV buffer, no scatter copy; jit shapes bucket per
            chunk length, not per padded prompt length), and the slot joins
            the running batch.
  step      ONE jitted decode step advances every live slot at once (each at
            its own depth — positions and lengths are per-sequence, and each
            slot carries its own sampling params + RNG key row).
  reclaim   finished sequences return their pages to the free list and their
            slot to the admit pool immediately; nobody waits for a batch.
  evict     if a slot's next token needs a page and the pool is exhausted,
            a live sequence is preempted (vLLM-style recompute preemption):
            its page references are released and it re-queues with prompt +
            generated-so-far, to be re-prefilled when space frees.

Policy (WHICH request admits, WHO gets evicted, per-tenant quotas) lives in
``serving/scheduler.py`` — the default ``FIFOScheduler`` reproduces the
pre-refactor hardwired behaviour (queue head admits, newest admission
evicts) decision-for-decision; ``SLOScheduler`` adds priority + fairness
admission, page quotas and least-progress / shared-aware eviction.

With ``prefix_cache`` enabled, pages are SHARED objects:

  alias     admit looks the prompt's full-page runs up in the content-
            addressed ``PrefixCache`` and aliases every matching page into
            the block table (retained, zero prefill), chunk-prefilling only
            the divergent tail. At least one tail token is always computed —
            the last position's logits seed the first generated token.
  publish   the tail's freshly computed full pages are indexed in the cache
            (which holds its own reference), so they outlive this sequence.
  dedup     a queued request with IDENTICAL content to a just-admitted one
            joins the batch by retaining that slot's pages outright — zero
            prefill, shared first-token logits (its own seed still draws its
            own stream).
  cow       before each decode step, a slot about to write into a page that
            still has other owners forks it (``fork_page`` copy, release the
            shared original) — no write ever mutates shared state, which is
            what keeps outputs bit-identical to sharing disabled.

Sampling is PER REQUEST: ``PagedRequest.temperature / top_k / seed`` ride
into the jitted step as (B,) arrays plus per-slot key rows, so one compiled
program serves any greedy/sampled mix. Keys derive from ``(seed, token
index)`` alone (``decode.request_key``), so a preempted request resumes its
sample stream deterministically. All-greedy batches keep using the original
5-argument greedy step — output byte-identical to the greedy-only batcher.

Throughput comes from the jit cache staying warm: the decode step sees one
static shape (max_batch x max_pages_per_seq), prefill sees at most
``prefill_chunk_pages`` distinct chunk shapes in total.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.config import ModelConfig
from repro.serving.decode import (make_paged_decode_step, request_key,
                                 sample_logits_per_seq)
from repro.serving.prefill import make_paged_prefill_step, run_prefill_chunks
from repro.serving.paged_cache import PagedKVCache, PrefixCache, chain_keys
from repro.serving.scheduler import FIFOScheduler, Scheduler

__all__ = ["PagedRequest", "ContinuousBatcher"]


@dataclasses.dataclass
class PagedRequest:
    """One generation request; ``out`` accumulates across preemptions.

    ``temperature <= 0`` decodes greedily (the default — byte-identical to
    the pre-sampling batcher); ``temperature > 0`` samples, optionally
    top-k-restricted, from the stream seeded by ``seed``. ``tenant`` and
    ``priority`` are policy inputs for ``SLOScheduler`` (quotas / admission
    order); the default ``FIFOScheduler`` ignores both. ``arrival`` is
    stamped by ``submit`` (fairness tiebreak within a priority class).
    """

    prompt: np.ndarray              # (S,) int32
    max_new: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    tenant: str = "default"
    priority: int = 0
    arrival: int = -1


@dataclasses.dataclass
class _Slot:
    req: PagedRequest
    page_ids: List[int]
    seq_len: int                    # tokens whose K/V are in the pool
    last_tok: int                   # next decode step's input token
    ticket: int = 0                 # admission order (FIFO eviction picks max)
    n_aliased: int = 0              # pages adopted from the cache / a twin


class ContinuousBatcher:
    def __init__(self, params_q, cfg: ModelConfig, cache: PagedKVCache,
                 max_batch: int = 4, use_pallas: bool = True,
                 prefill_chunk_pages: int = 4,
                 scheduler: Optional[Scheduler] = None,
                 prefix_cache: Union[bool, PrefixCache] = False,
                 prefix_cache_entries: Optional[int] = None,
                 gqa_pages_per_block: int = 1,
                 registry=None):
        self.params = params_q
        self.cfg = cfg
        self.cache = cache
        self.B = max_batch
        self.slots: List[Optional[_Slot]] = [None] * max_batch
        self.queue: Deque[PagedRequest] = collections.deque()
        self.done: List[PagedRequest] = []
        self.step_fn = jax.jit(make_paged_decode_step(
            cfg, use_pallas=use_pallas,
            gqa_pages_per_block=gqa_pages_per_block))
        self.sampled_step_fn = jax.jit(make_paged_decode_step(
            cfg, use_pallas=use_pallas, per_request=True,
            gqa_pages_per_block=gqa_pages_per_block))
        self.prefill_chunk_pages = max(int(prefill_chunk_pages), 1)
        self._prefill_chunk = jax.jit(make_paged_prefill_step(cfg))
        self.scheduler = scheduler if scheduler is not None else FIFOScheduler()
        if isinstance(prefix_cache, PrefixCache):
            self.prefix: Optional[PrefixCache] = prefix_cache
        else:
            self.prefix = PrefixCache(cache.allocator,
                                      max_entries=prefix_cache_entries) \
                if prefix_cache else None
        self._ticket = 0
        self._arrival = 0
        self._t_submit: Dict[int, float] = {}
        self.stats = {"steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "evictions": 0, "peak_pages": 0, "prefill_tokens": 0,
                      "prefill_tokens_saved": 0, "aliased_pages": 0,
                      "dedup_admits": 0, "cow_forks": 0}
        reg = registry if registry is not None else obs.get_registry()
        self.obs = {
            "ttft": reg.histogram(
                "serving_ttft_seconds", "Submit-to-first-token latency"),
            "tpot": reg.histogram(
                "serving_tpot_seconds",
                "One jitted decode step (time per output token)"),
            "prefill": reg.histogram(
                "serving_prefill_seconds",
                "Chunked prefill latency per admitted request"),
            "queue_depth": reg.gauge(
                "serving_queue_depth", "Queued requests at the last step"),
            "pages_in_use": reg.gauge(
                "serving_pages_in_use",
                "Live (non-reserved) pages at the last step"),
            "page_util": reg.gauge(
                "serving_page_utilization",
                "Live pages / allocatable pages at the last step"),
            "shared_pages": reg.gauge(
                "serving_shared_pages",
                "Distinct live pages with refcount > 1 at the last step"),
            "prefill_tokens": reg.counter(
                "serving_prefill_tokens_total", "Prompt tokens prefilled"),
            "tokens_saved": reg.counter(
                "serving_prefill_tokens_saved_total",
                "Prompt tokens skipped via prefix aliasing / dedup"),
            "aliased": reg.counter(
                "serving_aliased_pages_total",
                "Pages adopted from the prefix cache or a twin slot"),
            "dedup": reg.counter(
                "serving_dedup_admits_total",
                "Requests admitted by duplicate-content aliasing"),
            "cow": reg.counter(
                "serving_cow_forks_total", "Copy-on-write page forks"),
            "lru_retired": reg.counter(
                "serving_prefix_lru_retired_total",
                "Prefix-cache pages retired under allocation backpressure"),
            "preempt": reg.counter(
                "serving_preemptions_total",
                "Recompute preemptions (labelled by triggering reason)"),
            "decode_steps": reg.counter(
                "serving_decode_steps_total", "Jitted decode steps run"),
        }

    # -- admission ---------------------------------------------------------

    def submit(self, req: PagedRequest) -> None:
        if len(req.prompt) == 0:
            # the contiguous-prefill path silently decoded from a garbage
            # position here; generation with no conditioning is ill-defined
            raise ValueError("empty prompt: nothing to condition on")
        if len(req.prompt) + req.max_new > \
                self.cache.max_pages_per_seq * self.cache.page_size:
            raise ValueError("request exceeds max_pages_per_seq budget")
        req.arrival = self._arrival
        self._arrival += 1
        self._t_submit[id(req)] = time.monotonic()
        self.queue.append(req)

    def pages_needed(self, req: PagedRequest) -> int:
        """Pages an admit of ``req`` holds before any prefix aliasing (the
        scheduler's conservative quota estimate)."""
        plen = len(req.prompt) + len(req.out)
        extra = 1 if plen % self.cache.page_size == 0 else 0
        return self.cache.pages_for(plen) + extra

    def _record_first_token(self, req: PagedRequest) -> None:
        if not req.out:           # re-admits already produced their first token
            t0 = self._t_submit.pop(id(req), None)
            if t0 is not None:
                self.obs["ttft"].observe(time.monotonic() - t0)

    def _first_token(self, req: PagedRequest, logits_row) -> int:
        """Select the token that follows the prefilled prompt.

        Greedy requests take the argmax (the pre-sampling behaviour exactly);
        sampling requests draw through the SAME selection function, key and
        logits width as the jitted decode step (``sample_logits_per_seq``
        over the full padded-vocab row, key folded from (seed, token index))
        — categorical draws depend on the array width, so slicing to
        ``vocab_size`` here would fork a preempted request's sample stream
        on padded-vocab configs.
        """
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits_row[: self.cfg.vocab_size]))
        key = request_key(req.seed, len(req.out))
        tok = sample_logits_per_seq(
            logits_row[None], key[None],
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32))
        return int(tok[0])

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Allocate with prefix-cache backpressure: when the pool is dry,
        unreferenced cached runs are retired (LRU) before giving up."""
        if n <= 0:
            return []
        got = self.cache.allocator.alloc(n)
        if got is None and self.prefix is not None:
            retired = self.prefix.evict_lru(n - self.cache.allocator.num_free)
            self.obs["lru_retired"].inc(retired)
            got = self.cache.allocator.alloc(n)
        return got

    def _admit_one(self) -> bool:
        """Admit one scheduled request into a free slot. False if blocked.

        With the prefix cache on, matching full-page prompt runs are ALIASED
        (retained, zero prefill) and only the divergent tail — always at
        least one token, whose logits seed the first generated token — is
        chunk-prefilled; the tail's full pages are then published back to
        the cache.
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return False
        qi = self.scheduler.pick_admit(self)
        if qi is None:
            return False
        req = self.queue[qi]
        if len(req.out) >= req.max_new:     # nothing left to generate
            del self.queue[qi]
            self.done.append(req)
            return True
        psz = self.cache.page_size
        plen = len(req.prompt) + len(req.out)  # preempted: re-prefill both
        full = np.concatenate([req.prompt, np.asarray(req.out, np.int32)]) \
            if req.out else np.asarray(req.prompt, np.int32)
        keys: List[bytes] = []
        matched: List[int] = []
        if self.prefix is not None:
            keys = chain_keys(full, psz)
            # cap at (plen-1)//psz so >= 1 tail token is always computed
            matched = self.prefix.lookup(keys[: (plen - 1) // psz])
        # pages_needed includes the extra page a page-aligned prompt's first
        # decode write (position plen) needs — grabbed at admission so the
        # slot never scatters into the null page
        fresh = self._alloc_pages(self.pages_needed(req) - len(matched))
        if fresh is None:
            if matched:
                self.cache.allocator.release(matched)
            return False
        del self.queue[qi]
        page_ids = matched + fresh
        bt = jnp.asarray(self.cache.block_table_row(page_ids)[None])
        start = len(matched) * psz
        with obs.trace_span("serve.prefill", tokens=plen - start,
                            hist=self.obs["prefill"]):
            logits_row, self.cache.pools, n_chunks = run_prefill_chunks(
                self._prefill_chunk, self.params, self.cache.pools, full, bt,
                page_size=psz, chunk_pages=self.prefill_chunk_pages,
                start=start)
        self.stats["prefill_chunks"] += n_chunks
        self.stats["prefill_tokens"] += plen - start
        self.stats["prefill_tokens_saved"] += start
        self.stats["aliased_pages"] += len(matched)
        self.obs["prefill_tokens"].inc(plen - start)
        self.obs["tokens_saved"].inc(start)
        self.obs["aliased"].inc(len(matched))
        if self.prefix is not None:
            for i in range(len(matched), plen // psz):
                self.prefix.insert(keys[i], page_ids[i])
        nxt = self._first_token(req, logits_row)
        self.stats["prefills"] += 1
        self._ticket += 1
        slot = _Slot(req=req, page_ids=page_ids, seq_len=plen, last_tok=nxt,
                     ticket=self._ticket, n_aliased=len(matched))
        self._record_first_token(req)
        req.out.append(nxt)
        si = free[0]
        self.slots[si] = slot
        # duplicate-admit aliasing must run while this slot still holds its
        # pages (a finished-at-admit release would strand the twins)
        if self.prefix is not None:
            self._admit_twins(full, plen, page_ids, logits_row)
        self._finish_if_done(si)
        return True

    def _admit_twins(self, full, plen, page_ids, logits_row) -> None:
        """Admit queued requests whose CONTENT equals a just-admitted one by
        retaining its pages outright — zero prefill, zero fresh pages.

        The shared logits row is exactly what each twin's own prefill would
        have produced (same compiled programs, same inputs), and every twin
        samples its first token with its own (seed, index) key, so streams
        never fork. Divergence after that is handled by the decode-time COW
        fork: the first writer into the shared tail page copies it first.
        """
        qi = 0
        while qi < len(self.queue):
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                return
            q = self.queue[qi]
            if len(q.out) >= q.max_new:     # drained by _admit_one's path
                qi += 1
                continue
            q_full = np.concatenate([q.prompt, np.asarray(q.out, np.int32)]) \
                if q.out else np.asarray(q.prompt, np.int32)
            if len(q_full) != plen or not np.array_equal(q_full, full) or \
                    not self.scheduler.admissible(self, q, len(page_ids)):
                qi += 1
                continue
            self.cache.allocator.retain(page_ids)
            del self.queue[qi]
            nxt = self._first_token(q, logits_row)
            self.stats["prefills"] += 1
            self.stats["dedup_admits"] += 1
            self.stats["prefill_tokens_saved"] += plen
            self.stats["aliased_pages"] += len(page_ids)
            self.obs["dedup"].inc()
            self.obs["tokens_saved"].inc(plen)
            self.obs["aliased"].inc(len(page_ids))
            self._ticket += 1
            slot = _Slot(req=q, page_ids=list(page_ids), seq_len=plen,
                         last_tok=nxt, ticket=self._ticket,
                         n_aliased=len(page_ids))
            self._record_first_token(q)
            q.out.append(nxt)
            si = free[0]
            self.slots[si] = slot
            self._finish_if_done(si)

    def _admit(self) -> None:
        while self._admit_one():
            pass

    # -- eviction / reclamation --------------------------------------------

    def _release(self, i: int) -> None:
        """Drop slot i's page references; shared pages survive their co-owners
        (the prefix cache or a duplicate-admit twin)."""
        slot = self.slots[i]
        self.cache.allocator.release(slot.page_ids)
        self.slots[i] = None

    def _evict_one(self, reason: str = "page_capacity") -> bool:
        """Preempt the scheduler's victim back to the queue head."""
        vi = self.scheduler.pick_victim(self)
        if vi is None:
            return False  # never evict the only runner: no forward progress
        self.stats["evictions"] += 1
        self.obs["preempt"].inc(reason=reason)
        self.queue.appendleft(self.slots[vi].req)
        self._release(vi)
        return True

    # legacy name (pre-scheduler tests drive the eviction path directly);
    # under the default FIFOScheduler the victim IS the newest admission
    _evict_newest = _evict_one

    def _ensure_page_capacity(self) -> None:
        """Every live slot must own the page its next token writes into."""
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            while len(slot.page_ids) * self.cache.page_size <= slot.seq_len:
                got = self._alloc_pages(1)
                if got is not None:
                    slot.page_ids.extend(got)
                    break
                if not self._evict_one():
                    raise RuntimeError(
                        "page pool exhausted with a single live sequence; "
                        "grow n_pages or shrink max_new")
                if self.slots[i] is None:  # the victim was slot i itself
                    break

    def _ensure_cow(self) -> None:
        """Copy-on-write: no decode write may mutate a shared page.

        Each live slot's next token writes at ``(seq_len // psz, seq_len %
        psz)``; if that physical page still has other owners (a duplicate-
        admit twin — cached full-prefix pages are never the write target,
        they end strictly before position ``seq_len``), it is forked first:
        copy the rows into a fresh page, swap the block-table entry, release
        the shared original. Eviction of a co-owner can drop the count to 1
        mid-loop, in which case no fork is needed after all.
        """
        psz = self.cache.page_size
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            idx = slot.seq_len // psz   # _ensure_page_capacity ran: in range
            while self.cache.allocator.refcount(slot.page_ids[idx]) > 1:
                got = self._alloc_pages(1)
                if got is not None:
                    old = slot.page_ids[idx]
                    self.cache.fork_page(old, got[0])
                    slot.page_ids[idx] = got[0]
                    self.cache.allocator.release([old])
                    self.stats["cow_forks"] += 1
                    self.obs["cow"].inc()
                    break
                if not self._evict_one(reason="cow_fork"):
                    raise RuntimeError(
                        "page pool exhausted: cannot copy-on-write fork a "
                        "shared page; grow n_pages")
                if self.slots[i] is None:  # the victim was slot i itself
                    break

    def _finish_if_done(self, i: int) -> None:
        slot = self.slots[i]
        if slot is not None and len(slot.req.out) >= slot.req.max_new:
            self.done.append(slot.req)
            self._release(i)

    # -- the decode loop ---------------------------------------------------

    def _batch_arrays(self):
        bt = np.zeros((self.B, self.cache.max_pages_per_seq), np.int32)
        lens = np.zeros((self.B,), np.int32)
        toks = np.zeros((self.B, 1), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            bt[i] = self.cache.block_table_row(slot.page_ids)
            lens[i] = slot.seq_len
            toks[i, 0] = slot.last_tok
        return jnp.asarray(toks), jnp.asarray(bt), jnp.asarray(lens)

    def _sampling_arrays(self):
        """Per-slot (seeds, token_indices, temperatures, top_ks), all (B,).

        Plain host-side int/float fills — the key fold happens inside the
        jitted step, so no per-slot device round trips on the decode path.
        """
        seeds = np.zeros((self.B,), np.int32)
        idx = np.zeros((self.B,), np.int32)
        temps = np.zeros((self.B,), np.float32)
        top_ks = np.zeros((self.B,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None or slot.req.temperature <= 0.0:
                continue
            seeds[i] = slot.req.seed
            idx[i] = len(slot.req.out)
            temps[i] = slot.req.temperature
            top_ks[i] = slot.req.top_k
        return (jnp.asarray(seeds), jnp.asarray(idx), jnp.asarray(temps),
                jnp.asarray(top_ks))

    def step(self) -> int:
        """Admit + one decode step for all live slots. Returns #live."""
        self._admit()
        self._ensure_page_capacity()
        self._admit()  # eviction may have freed a slot a queued req fits in
        self._ensure_cow()  # after all admits: no write into a shared page
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        alloc = self.cache.allocator
        in_use = alloc.n_pages - alloc.reserved - alloc.num_free
        self.stats["peak_pages"] = max(self.stats["peak_pages"], in_use)
        self.obs["queue_depth"].set(len(self.queue))
        self.obs["pages_in_use"].set(in_use)
        allocatable = max(alloc.n_pages - alloc.reserved, 1)
        self.obs["page_util"].set(in_use / allocatable)
        held = {pid for i in live for pid in self.slots[i].page_ids}
        self.obs["shared_pages"].set(
            sum(1 for pid in held if alloc.refcount(pid) > 1))
        toks, bt, lens = self._batch_arrays()
        with obs.trace_span("serve.decode_step", live=len(live),
                            hist=self.obs["tpot"]):
            if any(self.slots[i].req.temperature > 0.0 for i in live):
                seeds, idx, temps, top_ks = self._sampling_arrays()
                next_toks, self.cache.pools = self.sampled_step_fn(
                    self.params, toks, self.cache.pools, bt, lens, seeds,
                    idx, temps, top_ks)
            else:  # all-greedy: the original 5-arg step, byte-identical
                next_toks, self.cache.pools = self.step_fn(
                    self.params, toks, self.cache.pools, bt, lens)
            next_toks = np.asarray(next_toks)   # the device sync
        self.stats["steps"] += 1
        self.obs["decode_steps"].inc()
        for i in live:
            slot = self.slots[i]
            slot.seq_len += 1
            if len(slot.req.out) >= slot.req.max_new:
                # defensive: a full request must never grow past its budget
                self._finish_if_done(i)
                continue
            slot.last_tok = int(next_toks[i, 0])
            slot.req.out.append(slot.last_tok)
            self._finish_if_done(i)
        return len(live)

    def _reset_run_state(self) -> None:
        """Drop per-run bookkeeping so a reused batcher does not accumulate
        state across ``run()`` calls (``done`` and the submit stamps used to
        grow without bound; durable metrics live in the registry)."""
        self.done.clear()
        self._t_submit.clear()

    def run(self, requests) -> List[List[int]]:
        """Serve a request list to completion; outputs in submission order.

        ``out`` is bounded by ``max_new`` at generation time (admit and step
        both stop appending at the budget), so no output truncation is
        needed here.
        """
        self._reset_run_state()
        for r in requests:
            self.submit(r)
        while self.queue or any(s is not None for s in self.slots):
            n = self.step()
            if n == 0 and self.queue:
                raise RuntimeError(
                    "queue stalled: prompts cannot be admitted (pool too "
                    "small, or every queued tenant is over quota)")
        if self.prefix is not None:
            # end-of-run drain: drop the cache's page references so the
            # allocator returns to fully free between request batches (the
            # cache amortises prefills WITHIN a run / server lifetime)
            self.prefix.clear()
        return [r.out for r in requests]
