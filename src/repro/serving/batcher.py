"""Continuous batching scheduler over the paged KV cache.

The toy server in ``launch/serve.py`` ran fixed batches to completion — a
batch of mixed-length requests waited for its longest member and its cache
slots were sized for ``max_len`` regardless of use. The batcher replaces that
with the production loop:

  admit     between decode steps, free batch slots are filled from the queue:
            the prompt is prefilled in PAGE-ALIGNED CHUNKS written straight
            into freshly allocated pages (``serving/prefill.py`` — no
            contiguous KV buffer, no scatter copy; jit shapes bucket per
            chunk length, not per padded prompt length), and the slot joins
            the running batch.
  step      ONE jitted decode step advances every live slot at once (each at
            its own depth — positions and lengths are per-sequence, and each
            slot carries its own sampling params + RNG key row).
  reclaim   finished sequences return their pages to the free list and their
            slot to the admit pool immediately; nobody waits for a batch.
  evict     if a slot's next token needs a page and the pool is exhausted,
            the most recently admitted sequence is preempted (vLLM-style
            recompute preemption): its pages are freed and it re-queues with
            prompt + generated-so-far, to be re-prefilled when space frees.

Sampling is PER REQUEST: ``PagedRequest.temperature / top_k / seed`` ride
into the jitted step as (B,) arrays plus per-slot key rows, so one compiled
program serves any greedy/sampled mix. Keys derive from ``(seed, token
index)`` alone (``decode.request_key``), so a preempted request resumes its
sample stream deterministically. All-greedy batches keep using the original
5-argument greedy step — output byte-identical to the greedy-only batcher.

Throughput comes from the jit cache staying warm: the decode step sees one
static shape (max_batch x max_pages_per_seq), prefill sees at most
``prefill_chunk_pages`` distinct chunk shapes in total.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving.decode import (make_paged_decode_step, request_key,
                                 sample_logits_per_seq)
from repro.serving.prefill import make_paged_prefill_step
from repro.serving.paged_cache import PagedKVCache

__all__ = ["PagedRequest", "ContinuousBatcher"]


@dataclasses.dataclass
class PagedRequest:
    """One generation request; ``out`` accumulates across preemptions.

    ``temperature <= 0`` decodes greedily (the default — byte-identical to
    the pre-sampling batcher); ``temperature > 0`` samples, optionally
    top-k-restricted, from the stream seeded by ``seed``.
    """

    prompt: np.ndarray              # (S,) int32
    max_new: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class _Slot:
    req: PagedRequest
    page_ids: List[int]
    seq_len: int                    # tokens whose K/V are in the pool
    last_tok: int                   # next decode step's input token
    ticket: int = 0                 # admission order (eviction picks max)


class ContinuousBatcher:
    def __init__(self, params_q, cfg: ModelConfig, cache: PagedKVCache,
                 max_batch: int = 4, use_pallas: bool = True,
                 prefill_chunk_pages: int = 4):
        self.params = params_q
        self.cfg = cfg
        self.cache = cache
        self.B = max_batch
        self.slots: List[Optional[_Slot]] = [None] * max_batch
        self.queue: Deque[PagedRequest] = collections.deque()
        self.done: List[PagedRequest] = []
        self.step_fn = jax.jit(make_paged_decode_step(cfg, use_pallas=use_pallas))
        self.sampled_step_fn = jax.jit(make_paged_decode_step(
            cfg, use_pallas=use_pallas, per_request=True))
        self.prefill_chunk_pages = max(int(prefill_chunk_pages), 1)
        self._prefill_chunk = jax.jit(make_paged_prefill_step(cfg))
        self.stats = {"steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "evictions": 0, "peak_pages": 0}

    # -- admission ---------------------------------------------------------

    def submit(self, req: PagedRequest) -> None:
        if len(req.prompt) == 0:
            # the contiguous-prefill path silently decoded from a garbage
            # position here; generation with no conditioning is ill-defined
            raise ValueError("empty prompt: nothing to condition on")
        if len(req.prompt) + req.max_new > \
                self.cache.max_pages_per_seq * self.cache.page_size:
            raise ValueError("request exceeds max_pages_per_seq budget")
        self.queue.append(req)

    def _first_token(self, req: PagedRequest, logits_row) -> int:
        """Select the token that follows the prefilled prompt.

        Greedy requests take the argmax (the pre-sampling behaviour exactly);
        sampling requests draw through the SAME selection function, key and
        logits width as the jitted decode step (``sample_logits_per_seq``
        over the full padded-vocab row, key folded from (seed, token index))
        — categorical draws depend on the array width, so slicing to
        ``vocab_size`` here would fork a preempted request's sample stream
        on padded-vocab configs.
        """
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits_row[: self.cfg.vocab_size]))
        key = request_key(req.seed, len(req.out))
        tok = sample_logits_per_seq(
            logits_row[None], key[None],
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32))
        return int(tok[0])

    def _admit_one(self) -> bool:
        """Chunk-prefill the queue head into a free slot. False if blocked."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return False
        req = self.queue[0]
        if len(req.out) >= req.max_new:     # nothing left to generate
            self.queue.popleft()
            self.done.append(req)
            return True
        plen = len(req.prompt) + len(req.out)  # preempted: re-prefill both
        n_pages = self.cache.pages_for(plen)
        # when the prompt exactly fills its pages, the first decode write
        # (position plen) needs one more page — grab it at admission so the
        # slot never scatters into the null page
        extra = 1 if plen % self.cache.page_size == 0 else 0
        page_ids = self.cache.allocator.alloc(n_pages + extra)
        if page_ids is None:
            return False
        self.queue.popleft()
        psz = self.cache.page_size
        full = np.concatenate([req.prompt, np.asarray(req.out, np.int32)]) \
            if req.out else np.asarray(req.prompt, np.int32)
        bt = jnp.asarray(self.cache.block_table_row(page_ids)[None])
        chunk_tokens = self.prefill_chunk_pages * psz
        off = 0
        logits = None
        while off < plen:
            n_tok = min(chunk_tokens, plen - off)
            c = self.cache.pages_for(n_tok) * psz   # pad tail to a page multiple
            toks = np.zeros((1, c), np.int32)
            toks[0, :n_tok] = full[off: off + n_tok]
            logits, self.cache.pools = self._prefill_chunk(
                self.params, jnp.asarray(toks), self.cache.pools, bt,
                jnp.int32(off))
            self.stats["prefill_chunks"] += 1
            last_off, off = off, off + n_tok
        nxt = self._first_token(req, logits[0, (plen - 1) - last_off])
        self.stats["prefills"] += 1
        slot = _Slot(req=req, page_ids=page_ids, seq_len=plen, last_tok=nxt,
                     ticket=self.stats["prefills"])
        req.out.append(nxt)
        self.slots[free[0]] = slot
        self._finish_if_done(free[0])
        return True

    def _admit(self) -> None:
        while self._admit_one():
            pass

    # -- eviction / reclamation --------------------------------------------

    def _release(self, i: int) -> None:
        slot = self.slots[i]
        self.cache.allocator.free(slot.page_ids)
        self.slots[i] = None

    def _evict_newest(self) -> bool:
        """Preempt the youngest live sequence back to the queue head."""
        live = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if len(live) <= 1:
            return False  # never evict the only runner: no forward progress
        i, slot = max(live, key=lambda t: t[1].ticket)
        self.stats["evictions"] += 1
        self.queue.appendleft(slot.req)
        self._release(i)
        return True

    def _ensure_page_capacity(self) -> None:
        """Every live slot must own the page its next token writes into."""
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            while len(slot.page_ids) * self.cache.page_size <= slot.seq_len:
                got = self.cache.allocator.alloc(1)
                if got is not None:
                    slot.page_ids.extend(got)
                    break
                if not self._evict_newest():
                    raise RuntimeError(
                        "page pool exhausted with a single live sequence; "
                        "grow n_pages or shrink max_new")
                if self.slots[i] is None:  # evicted ourselves (i was newest)
                    break

    def _finish_if_done(self, i: int) -> None:
        slot = self.slots[i]
        if slot is not None and len(slot.req.out) >= slot.req.max_new:
            self.done.append(slot.req)
            self._release(i)

    # -- the decode loop ---------------------------------------------------

    def _batch_arrays(self):
        bt = np.zeros((self.B, self.cache.max_pages_per_seq), np.int32)
        lens = np.zeros((self.B,), np.int32)
        toks = np.zeros((self.B, 1), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            bt[i] = self.cache.block_table_row(slot.page_ids)
            lens[i] = slot.seq_len
            toks[i, 0] = slot.last_tok
        return jnp.asarray(toks), jnp.asarray(bt), jnp.asarray(lens)

    def _sampling_arrays(self):
        """Per-slot (seeds, token_indices, temperatures, top_ks), all (B,).

        Plain host-side int/float fills — the key fold happens inside the
        jitted step, so no per-slot device round trips on the decode path.
        """
        seeds = np.zeros((self.B,), np.int32)
        idx = np.zeros((self.B,), np.int32)
        temps = np.zeros((self.B,), np.float32)
        top_ks = np.zeros((self.B,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None or slot.req.temperature <= 0.0:
                continue
            seeds[i] = slot.req.seed
            idx[i] = len(slot.req.out)
            temps[i] = slot.req.temperature
            top_ks[i] = slot.req.top_k
        return (jnp.asarray(seeds), jnp.asarray(idx), jnp.asarray(temps),
                jnp.asarray(top_ks))

    def step(self) -> int:
        """Admit + one decode step for all live slots. Returns #live."""
        self._admit()
        self._ensure_page_capacity()
        self._admit()  # eviction may have freed a slot a queued req fits in
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        in_use = self.cache.allocator.n_pages - self.cache.allocator.reserved \
            - self.cache.allocator.num_free
        self.stats["peak_pages"] = max(self.stats["peak_pages"], in_use)
        toks, bt, lens = self._batch_arrays()
        if any(self.slots[i].req.temperature > 0.0 for i in live):
            seeds, idx, temps, top_ks = self._sampling_arrays()
            next_toks, self.cache.pools = self.sampled_step_fn(
                self.params, toks, self.cache.pools, bt, lens, seeds, idx,
                temps, top_ks)
        else:  # all-greedy: the original 5-arg step, byte-identical output
            next_toks, self.cache.pools = self.step_fn(
                self.params, toks, self.cache.pools, bt, lens)
        next_toks = np.asarray(next_toks)
        self.stats["steps"] += 1
        for i in live:
            slot = self.slots[i]
            slot.seq_len += 1
            if len(slot.req.out) >= slot.req.max_new:
                # defensive: a full request must never grow past its budget
                self._finish_if_done(i)
                continue
            slot.last_tok = int(next_toks[i, 0])
            slot.req.out.append(slot.last_tok)
            self._finish_if_done(i)
        return len(live)

    def run(self, requests) -> List[List[int]]:
        """Serve a request list to completion; outputs in submission order.

        ``out`` is bounded by ``max_new`` at generation time (admit and step
        both stop appending at the budget), so no output truncation is
        needed here.
        """
        for r in requests:
            self.submit(r)
        while self.queue or any(s is not None for s in self.slots):
            n = self.step()
            if n == 0 and self.queue:
                raise RuntimeError("queue stalled: prompts cannot be admitted")
        return [r.out for r in requests]
