"""Minimal property-testing fallback for environments without ``hypothesis``.

``tests/conftest.py`` calls :func:`install` only when the real package is
missing (the dev container bakes jax but not hypothesis, and installing is
not always possible). CI installs the real hypothesis from
requirements-dev.txt, so this shim is a fallback, never a replacement.

Implements exactly the surface the test suite uses — ``given``, ``settings``,
``assume``, and the ``integers`` / ``floats`` / ``sampled_from`` /
``booleans`` strategies — with deterministic draws seeded per test name, so a
failure reproduces on re-run.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import sys
import types

import numpy as np

__all__ = ["install"]

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


class _Unsatisfied(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


def given(*strategies):
    """Run the test body ``max_examples`` times with deterministic draws.

    The drawn arguments fill the test's TRAILING parameters; the wrapper's
    signature drops them so pytest does not mistake them for fixtures."""
    def decorate(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if len(params) < len(strategies):
            raise TypeError(f"{fn.__name__} takes {len(params)} args but "
                            f"@given supplies {len(strategies)}")
        kept = params[:len(params) - len(strategies)]
        seed = int.from_bytes(
            hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big")

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_hyp_settings",
                        {}).get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(seed)
            ran = 0
            rejected = 0
            while ran < n:
                drawn = [s.example(rng) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except _Unsatisfied:
                    rejected += 1
                    if rejected > max(10 * n, 100):  # real hypothesis errors too
                        raise AssertionError(
                            f"{fn.__name__}: assume() rejected {rejected} draws"
                            f" for {ran} accepted — unsatisfiable property")
                    continue
                except Exception:
                    print(f"[hypothesis-fallback] falsifying example for "
                          f"{fn.__name__}: {drawn!r}", file=sys.stderr)
                    raise
                ran += 1

        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__  # keep pytest off fn's original signature
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records max_examples on the (already @given-wrapped) test."""
    def decorate(fn):
        fn._mini_hyp_settings = {"max_examples": max_examples}
        return fn
    return decorate


def install():
    """Register this shim as ``hypothesis`` / ``hypothesis.strategies``.

    No-op if a ``hypothesis`` module is already importable or installed."""
    if "hypothesis" in sys.modules:
        return
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.booleans = booleans
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            filter_too_much="filter_too_much")
    hyp.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
