"""Qwen3-4B [dense GQA, qk-norm]. Source: hf:Qwen/Qwen3-4B (family per Qwen/Qwen3-8B).

head_dim=128 (q proj 2560 -> 32*128=4096).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    activation="silu",
    gated_mlp=True,
    qk_norm=True,
    pos_emb="rope",
    rope_theta=1e6,
    norm="rmsnorm",
    block_pattern="dense",
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
