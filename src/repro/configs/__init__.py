"""Architecture config registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture (public-literature config, see each module's
docstring for the source) plus the paper's own OPT family.
"""
from __future__ import annotations

import importlib

_ARCHS = {
    "zamba2-7b": "zamba2_7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-4b": "qwen3_4b",
    "command-r-35b": "command_r_35b",
    "yi-6b": "yi_6b",
    "mamba2-2.7b": "mamba2_2_7b",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "opt-1.3b": "opt_paper",
    "opt-13b": "opt_paper",
    "opt-125m": "opt_paper",
    "opt-tiny": "opt_paper",
}


def list_archs(assigned_only: bool = True):
    ids = list(_ARCHS)
    return [a for a in ids if not a.startswith("opt-")] if assigned_only else ids


def get_config(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.config(arch) if hasattr(mod, "config") else mod.CONFIG
