"""Mamba2-2.7B [pure SSM / SSD, attention-free]. Source: arXiv:2405.21060.

d_inner = 2*2560 = 5120, head_dim=64 -> 80 SSD heads, d_state=128.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pos_emb="none",
    norm="rmsnorm",
    block_pattern="ssm",
    ssm=SSMConfig(d_state=128, head_dim=64, conv_width=4, expand=2, n_groups=1, chunk=128),
    max_seq_len=524288,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
