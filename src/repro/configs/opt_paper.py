"""OPT family (the paper's own models, Zhang et al. 2022): ReLU FFN, learned
positions, LayerNorm, MHA, biases everywhere — the arch where the paper's
scaling invariance is EXACT.

``opt-tiny`` is the in-harness benchmark model (CPU-trainable).
"""
from repro.models.config import ModelConfig

_SIZES = {
    # n_layers, d_model, n_heads, d_ff
    "opt-125m": (12, 768, 12, 3072),
    "opt-1.3b": (24, 2048, 32, 8192),
    "opt-13b": (40, 5120, 40, 20480),
    "opt-tiny": (4, 128, 4, 512),
}


def config(arch: str = "opt-1.3b") -> ModelConfig:
    L, d, h, f = _SIZES[arch]
    return ModelConfig(
        name=arch,
        n_layers=L,
        d_model=d,
        n_heads=h,
        n_kv_heads=h,
        d_ff=f,
        vocab_size=50272 if arch != "opt-tiny" else 512,
        activation="relu",
        gated_mlp=False,
        use_bias=True,
        pos_emb="learned",
        norm="layernorm",
        block_pattern="dense",
        max_seq_len=2048 if arch != "opt-tiny" else 512,
        vocab_pad_multiple=16,
    )
