"""SeamlessM4T-medium [audio enc-dec]. Source: arXiv:2308.11596.

Text enc-dec backbone: 12 encoder + 12 decoder layers, d=1024, 16 heads,
ReLU FFN, LayerNorm, learned-free (sinusoidal in the original; we use RoPE-free
learned positions). Speech frontend is a STUB (precomputed frame embeddings).
ReLU makes the paper's scaling invariance EXACT for this arch.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    activation="relu",
    gated_mlp=False,
    use_bias=True,
    pos_emb="learned",
    norm="layernorm",
    block_pattern="dense",
    frontend="audio",
    frontend_len=4096,
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
