"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) [MoE 64e top-6].

Source: hf:moonshotai/Moonlight-16B-A3B (DeepSeek-V3-style fine-grained MoE).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    activation="silu",
    gated_mlp=True,
    pos_emb="rope",
    rope_theta=5e4,
    norm="rmsnorm",
    block_pattern="moe",
    moe=MoEConfig(num_experts=64, top_k=6, capacity_factor=1.25),
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
