"""Yi-6B [dense GQA, llama-arch]. Source: arXiv:2403.04652 + hf:01-ai/Yi-6B."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    activation="silu",
    gated_mlp=True,
    pos_emb="rope",
    rope_theta=5e6,
    norm="rmsnorm",
    block_pattern="dense",
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
