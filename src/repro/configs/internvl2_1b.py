"""InternVL2-1B [VLM] — Qwen2-0.5B language backbone + InternViT frontend STUB.

Source: arXiv:2404.16821 + hf:OpenGVLab/InternVL2-1B. The vision tower is a
stub per assignment spec (input_specs provides precomputed patch embeddings).
Qwen2 backbone uses attention qkv biases.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    activation="silu",
    gated_mlp=True,
    attn_qkv_bias=True,
    pos_emb="rope",
    rope_theta=1e6,
    norm="rmsnorm",
    block_pattern="dense",
    frontend="vision",
    frontend_len=256,
    tie_embeddings=True,
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
