"""Command-R 35B [dense GQA, no-bias]. Source: hf:CohereForAI/c4ai-command-r-v01."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    activation="silu",
    gated_mlp=True,
    use_bias=False,
    pos_emb="rope",
    rope_theta=8e6,
    norm="layernorm",
    block_pattern="dense",
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
