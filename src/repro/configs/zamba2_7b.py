"""Zamba2-7B [hybrid] — Mamba2 blocks + shared attention block.

Source: arXiv:2411.15242 (Zamba2 suite). 81 blocks, d_model=3584, 32 heads
(kv=32), shared transformer block every 6th position, Mamba2 ssm_state=64.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    activation="silu",
    gated_mlp=True,
    pos_emb="rope",
    norm="rmsnorm",
    block_pattern="hybrid",
    hybrid_period=6,
    ssm=SSMConfig(d_state=64, head_dim=64, conv_width=4, expand=2, n_groups=1, chunk=128),
    max_seq_len=524288,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
