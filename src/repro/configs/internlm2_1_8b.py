"""InternLM2-1.8B [dense GQA]. Source: arXiv:2403.17297 + hf:internlm/internlm2-1_8b."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    activation="silu",
    gated_mlp=True,
    pos_emb="rope",
    norm="rmsnorm",
    block_pattern="dense",
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
