"""Phi-3.5-MoE (42B total / 6.6B active) [MoE 16e top-2].

Source: hf:microsoft/Phi-3.5-MoE-instruct. head_dim=128 (32*128=4096).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    activation="silu",
    gated_mlp=True,
    pos_emb="rope",
    norm="layernorm",
    block_pattern="moe",
    moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25),
    max_seq_len=32768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
