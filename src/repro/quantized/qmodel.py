"""Quantized model packing for the serving path.

``pack_model`` converts every quantizable weight into a packed ``QTensor``
(uint32 codes + group scale/zero). The model's scan bodies dequantize each
layer's QTensor slice on the fly (see repro.models.model), so serving holds
only the packed form in HBM — the ultra-low-bit memory win the paper targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, QTensor, quantize_tensor
from repro.core.rtn import map_quantizable

__all__ = ["pack_model", "packed_bytes", "dense_bytes", "cache_bytes",
           "serving_memory_report"]


def pack_model(params, qcfg: QuantConfig, only=None):
    """Replace quantizable weight leaves with QTensors.

    Works on fake-quant params (values already on the grid -> packing is
    lossless) or raw params (packing IS the RTN quantization).
    """
    return map_quantizable(params, lambda w, p: quantize_tensor(w, qcfg), only=only)


def packed_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.memory_bytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def cache_bytes(cache) -> int:
    """Total bytes of any KV-cache tree (contiguous cache dict, paged page
    pools, int8 code + scale layouts alike) — the serving memory term that
    dominates once weights are ultra-low-bit."""
    return sum(int(leaf.size * jnp.dtype(leaf.dtype).itemsize)
               for leaf in jax.tree.leaves(cache))


def serving_memory_report(params_q, cache) -> dict:
    """Weight vs KV-cache memory split for a serving configuration.

    ``kv_fraction`` is the headline number paging attacks: with 2-bit
    weights the cache is the dominant term, so cache bytes must track live
    tokens (pages), not allocated capacity.
    """
    wb, cb = packed_bytes(params_q), cache_bytes(cache)
    return {"weight_bytes": wb, "kv_bytes": cb,
            "kv_fraction": cb / max(wb + cb, 1)}


def dense_bytes(params, dtype_bytes: int = 2) -> int:
    """What the same tree would cost un-quantized at fp16/bf16."""
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            lead = leaf.packed.shape[:-2]
            n = 1
            for d in lead + leaf.shape:
                n *= d
            total += n * dtype_bytes
        else:
            total += leaf.size * dtype_bytes
    return total
