from repro.quantized.qmodel import pack_model, packed_bytes, dense_bytes

__all__ = ["pack_model", "packed_bytes", "dense_bytes"]
