"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch opt-tiny --steps 200 \
        --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (local mesh); the same step function lowers
onto the production mesh via dryrun.py. Integrates: deterministic pipeline,
AdamW, sharded checkpointing (async), straggler watchdog, resilient restart.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.dist.fault import StepWatchdog, run_resilient
from repro.ckpt.checkpoint import CheckpointManager, latest_step
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_init


def train(arch: str = "opt-tiny", steps: int = 100, batch: int = 8, seq: int = 256,
          lr: float = 3e-4, ckpt_dir: str = None, save_every: int = 50,
          reduced: bool = True, log_every: int = 10, seed: int = 0,
          params=None, cfg=None):
    cfg = cfg or (get_config(arch).reduced() if reduced else get_config(arch))
    if seq > cfg.max_seq_len:
        cfg = dataclasses.replace(cfg, max_seq_len=seq)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 5))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    key = jax.random.PRNGKey(seed)
    params = params if params is not None else init_params(key, cfg)
    opt_state = adamw_init(params)

    data_cfg = DataConfig(seq_len=seq, global_batch=batch, vocab_size=cfg.vocab_size)
    batch_at = make_pipeline(data_cfg)

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and latest_step(ckpt.dir) is not None:
        (params, opt_state), manifest = ckpt.restore()
        start = manifest["step"]
        print(f"[train] resumed from step {start}")

    watchdog = StepWatchdog()
    losses = []

    def one_step(state, step):
        p, o = state
        tokens = jnp.asarray(batch_at(step))
        p, o, metrics = step_fn(p, o, {"tokens": tokens})
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f}",
                  flush=True)
        return (p, o)

    t0 = time.monotonic()
    if ckpt:
        state, events = run_resilient(one_step, (params, opt_state), n_steps=steps,
                                      ckpt=ckpt, save_every=save_every,
                                      start_step=start, watchdog=watchdog)
        params, opt_state = state
    else:
        state = (params, opt_state)
        for s in range(start, steps):
            state = one_step(state, s)
        params, opt_state = state
    dt = time.monotonic() - t0
    print(f"[train] {steps - start} steps in {dt:.1f}s "
          f"({(steps - start) / max(dt, 1e-9):.2f} it/s); straggler flags: {watchdog.flagged}")
    return params, losses, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--full", action="store_true", help="full-size config (not reduced)")
    args = ap.parse_args()
    train(args.arch, args.steps, args.batch, args.seq, args.lr, args.ckpt_dir,
          args.save_every, reduced=not args.full)


if __name__ == "__main__":
    main()
