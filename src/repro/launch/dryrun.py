import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/initialization (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --all --jobs-file cells.txt

Per cell this proves: the sharding config is coherent (SPMD partitioning
succeeds), the program fits (memory_analysis), and yields the roofline inputs
(cost_analysis + Δ-trick per-layer rates + collective-bytes parse).
Results append to artifacts/dryrun/<cell>.json.
"""
import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.dist import compat
from repro.dist.sharding import (ShardingRules, param_specs, opt_state_specs,
                                 cache_specs, data_spec, to_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_bytes, extrapolate, roofline_terms,
                                   model_flops, HW)
from repro.launch.steps import (SHAPES, shape_applicable, make_train_step,
                                make_serve_step, make_prefill_step, input_specs)
from repro.models.config import ModelConfig

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _scaled_cfg(cfg: ModelConfig, n_layers: int, seq: int) -> ModelConfig:
    """Δ-trick config: L layers with EVERY scan fully unrolled so XLA cost
    analysis counts each iteration (while bodies are otherwise counted once —
    see launch/roofline.py). Memory/schedule still come from the real
    (scanned) full-depth compile.

    The attention KV-chunk is raised so at most 64 chunks unroll — attention
    FLOPs are chunk-size-invariant (only the online-softmax correction ops
    scale with chunk count), so this caps compile time without distorting the
    measurement. The SSD chunk stays at its deployed size (its intra-chunk
    quadratic DOES depend on chunk) — its inter-chunk recurrence body is a
    tiny state update, cheap to unroll fully.
    """
    kw = {"n_layers": n_layers, "unroll_layers": True, "unroll_inner": True,
          "attn_chunk": max(cfg.attn_chunk, (seq + 63) // 64)}
    if cfg.is_enc_dec:
        kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _delta_layers(cfg: ModelConfig):
    if cfg.block_pattern == "hybrid":
        return cfg.hybrid_period, 2 * cfg.hybrid_period
    return 2, 3


def _shardings_for(kind, rules, structs, cfg, batch):
    """in_shardings tuple matching the step args."""
    if kind == "train":
        params_s, opt_s, batch_s = structs
        pspec = param_specs(rules, params_s)
        ospec = opt_state_specs(rules, params_s)
        bspec = {"tokens": data_spec(rules, batch)}
        if "vision_embeds" in batch_s:
            bspec["vision_embeds"] = jax.sharding.PartitionSpec(*data_spec(rules, batch), None)
        if "enc_embeds" in batch_s:
            bspec["enc_embeds"] = jax.sharding.PartitionSpec(*data_spec(rules, batch), None)
        return (pspec, ospec, bspec)
    if kind == "prefill":
        params_s, batch_s = structs
        pspec = param_specs(rules, params_s)
        bspec = {"tokens": data_spec(rules, batch)}
        if "vision_embeds" in batch_s:
            bspec["vision_embeds"] = jax.sharding.PartitionSpec(*data_spec(rules, batch), None)
        if "enc_embeds" in batch_s:
            bspec["enc_embeds"] = jax.sharding.PartitionSpec(*data_spec(rules, batch), None)
        return (pspec, bspec)
    # decode
    params_s, tok_s, cache_s, idx_s = structs
    pspec = param_specs(rules, params_s)
    cspec = cache_specs(rules, cfg, batch)
    if isinstance(cache_s, dict) and "cross" in cache_s and "cross" not in cspec:
        cspec = dict(cspec)
        cspec["cross"] = (jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec())
    tspec = data_spec(rules, batch)
    return (pspec, tspec, cspec, jax.sharding.PartitionSpec())


def _compile_once(cfg: ModelConfig, shape: str, mesh, rules, *, want_text=False,
                  accum: int = 1):
    kind, structs = input_specs(cfg, shape)
    info = SHAPES[shape]
    if kind == "train":
        cfg_t = dataclasses.replace(cfg, remat=True)
        step = make_train_step(cfg_t, accum_steps=accum)
    elif kind == "prefill":
        # VLM prefill holds frontend_len patch positions + seq tokens
        extra = cfg.frontend_len if cfg.frontend == "vision" else 0
        step = make_prefill_step(cfg, max_len=info["seq"] + extra)
    else:
        step = make_serve_step(cfg)
    in_sh = _shardings_for(kind, rules, structs, cfg, info["batch"])
    in_sh = to_shardings(mesh, in_sh)
    t0 = time.monotonic()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh).lower(*structs)
        compiled = lowered.compile()
    dt = time.monotonic() - t0
    ca = compat.cost_analysis(compiled)
    ma = compiled.memory_analysis()
    txt = compiled.as_text() if want_text else None
    coll = collective_bytes(compiled.as_text())
    return {
        "kind": kind,
        "compile_s": round(dt, 2),
        "flops_dev": float(ca.get("flops", 0.0)),
        "bytes_dev": float(ca.get("bytes accessed", 0.0)),
        "coll_dev": coll,
        "memory": None if ma is None else {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "hlo_text": txt,
    }


# §Perf hillclimb knobs: --opt a,b,c applies these config/rule overrides and
# writes the cell artifact under a suffixed name (baselines stay untouched).
OPTS = {
    "remat_dots": {"remat_policy": "dots"},
    "remat_dots_all": {"remat_policy": "dots_all"},
    "bf16_scores": {"attn_softmax_dtype": "bfloat16"},
    "repeat_kv": {"gqa_repeat_kv": True},
    "kv_int8": {"kv_cache_dtype": "int8"},
    "chunk4k": {"attn_chunk": 4096},
    "chunk8k": {"attn_chunk": 8192},
    "chunk32k": {"attn_chunk": 32768},
    "heads_shard": {},  # rules-level (long_decode_shard="heads")
    "cap1": {},         # moe capacity_factor 1.25 -> 1.0 (handled in run_cell)
    "accum4": {},       # 4x gradient accumulation (handled in run_cell)
}


def run_cell(arch: str, shape: str, *, multi_pod: bool, delta: bool = True,
             zero1: bool = False, keep_text: bool = False, opts=()) -> dict:
    cfg = get_config(arch)
    overrides = {}
    for o in opts:
        overrides.update(OPTS[o])
    if "cap1" in opts and cfg.moe is not None:
        overrides["moe"] = dataclasses.replace(cfg.moe, capacity_factor=1.0)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rec = {"arch": arch, "shape": shape, "opts": list(opts),
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    if not shape_applicable(cfg, shape):
        rec.update(ok=True, skipped=True,
                   note="long_500k skipped: pure full-attention arch (DESIGN.md)")
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = ShardingRules(
            mesh, cfg, zero1=zero1,
            long_decode_shard="heads" if "heads_shard" in opts else "seq")
        n_chips = 512 if multi_pod else 256
        accum = 4 if "accum4" in opts else 1
        full = _compile_once(cfg, shape, mesh, rules, want_text=keep_text,
                             accum=accum)
        rec.update(ok=True, kind=full["kind"], compile_s=full["compile_s"],
                   memory=full["memory"], coll_schedule=full["coll_dev"])

        if delta and not multi_pod:
            l2, l3 = _delta_layers(cfg)
            seq = SHAPES[shape]["seq"]
            r2 = _compile_once(_scaled_cfg(cfg, l2, seq), shape, mesh, rules, accum=accum)
            r3 = _compile_once(_scaled_cfg(cfg, l3, seq), shape, mesh, rules, accum=accum)
            lf = cfg.n_layers
            flops_dev = extrapolate(r2["flops_dev"], r3["flops_dev"], l2, l3, lf)
            bytes_dev = extrapolate(r2["bytes_dev"], r3["bytes_dev"], l2, l3, lf)
            c2 = sum(r2["coll_dev"].values())
            c3 = sum(r3["coll_dev"].values())
            coll_dev = extrapolate(c2, c3, l2, l3, lf)
            terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
            info = SHAPES[shape]
            n_tokens = info["batch"] * (info["seq"] if full["kind"] != "decode" else 1)
            mf = model_flops(cfg, n_tokens, train=(full["kind"] == "train"))
            terms["model_flops_global"] = mf
            terms["hlo_flops_global"] = flops_dev * n_chips
            terms["useful_ratio"] = mf / max(flops_dev * n_chips, 1.0)
            step_time = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
            terms["mfu_bound"] = (mf / n_chips / HW["peak_flops"]) / max(step_time, 1e-12)
            rec.update(flops_dev=flops_dev, bytes_dev=bytes_dev, coll_dev=coll_dev,
                       roofline=terms, delta_layers=[l2, l3])
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def _cell_path(arch, shape, multi_pod, opts=()):
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = ("__opt-" + "-".join(opts)) if opts else ""
    return ART / f"{arch}__{shape}__{mesh}{suffix}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--no-delta", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in subprocesses")
    ap.add_argument("--opt", default="",
                    help="comma-separated perf knobs: " + ",".join(OPTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    opts = tuple(o for o in args.opt.split(",") if o)
    ART.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s, mp)
                 for a in list_archs()
                 for s in SHAPES
                 for mp in (False, True)]
        todo = [(a, s, mp) for a, s, mp in cells
                if args.force or not _cell_path(a, s, mp).exists()]
        print(f"[dryrun] {len(todo)}/{len(cells)} cells to run")
        for i, (a, s, mp) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s] + (["--multi-pod"] if mp else [])
            t0 = time.monotonic()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ}, timeout=3600)
            ok = "?"
            p = _cell_path(a, s, mp)
            if p.exists():
                ok = json.loads(p.read_text()).get("ok")
            print(f"[dryrun {i+1}/{len(todo)}] {a} {s} mp={mp} ok={ok} "
                  f"({time.monotonic()-t0:.0f}s)", flush=True)
            if r.returncode != 0:
                print(r.stderr[-1500:], flush=True)
        return

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   delta=not args.no_delta, zero1=args.zero1, opts=opts)
    out = _cell_path(args.arch, args.shape, args.multi_pod, opts)
    out.write_text(json.dumps(rec, indent=1, default=str))
    if rec.get("memory"):
        print(f"memory_analysis: {rec['memory']}")
    if rec.get("roofline"):
        rl = {k: v for k, v in rec["roofline"].items()
              if isinstance(v, (int, float))}
        print(f"roofline: {rl}")
    print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "ok") if k in rec}))
    if not rec["ok"] and "error" in rec:
        print(rec["error"])
        print(rec.get("trace", ""))
        sys.exit(1)


if __name__ == "__main__":
    main()
