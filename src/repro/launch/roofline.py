"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds-per-step per chip:

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

cost_analysis()/HLO both count a scan (while-loop) body ONCE, so absolute
numbers come from the Δ-trick: compile the same program at L2 and L3 layers;
the difference is the exact per-layer per-device cost; the full-depth value is
linear extrapolation (validated in tests/test_roofline.py). Collective bytes
are parsed from ``compiled.as_text()`` result/operand shapes.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]

# TPU v5e per-chip constants (published spec numbers)
HW = {
    "peak_flops": 197e12,   # bf16
    "hbm_bw": 819e9,        # bytes/s
    "ici_bw": 50e9,         # bytes/s/link (~45-50 GB/s on v5e)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind byte counts from an HLO module text (counts '-start' once,
    skips '-done'). Bytes = max(result, operands) per op — a conservative
    proxy for the data a collective moves through the links."""
    out: Counter = Counter()
    for m in _LINE_RE.finditer(hlo_text):
        result_t, op, _start, operands = m.groups()
        rb = _type_bytes(result_t)
        ob = _type_bytes(operands)
        out[op] += max(rb, ob)
    return dict(out)


def extrapolate(v2: float, v3: float, l2: int, l3: int, l_full: int) -> float:
    """Linear-in-layers extrapolation of a per-device cost."""
    slope = (v3 - v2) / max(l3 - l2, 1)
    return v2 + slope * (l_full - l2)


def roofline_terms(flops_dev: float, bytes_dev: float, coll_dev: float) -> dict:
    terms = {
        "compute_s": flops_dev / HW["peak_flops"],
        "memory_s": bytes_dev / HW["hbm_bw"],
        "collective_s": coll_dev / HW["ici_bw"],
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    terms["dominant"] = dominant
    # roofline fraction: useful-step-time ratio if the dominant term were the
    # only cost vs. a naive serial sum (overlap-free) execution
    terms["overlap_fraction"] = bound / total if total > 0 else 0.0
    return terms


def model_flops(cfg, n_tokens: int, train: bool) -> float:
    """6·N·D (train) or 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count()
    return (6.0 if train else 2.0) * n * n_tokens
