"""Step factories + input ShapeDtypeStruct specs for every (arch × shape).

Shapes (assignment spec):
    train_4k     seq 4096   batch 256   -> train_step (fwd+bwd+AdamW)
    prefill_32k  seq 32768  batch 32    -> serve_prefill (quantized weights)
    decode_32k   seq 32768  batch 128   -> serve_step (1 new token, KV cache)
    long_500k    seq 524288 batch 1     -> serve_step (SSM/hybrid only)

``long_500k`` is SKIPPED for pure full-attention archs per the assignment
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.models.config import ModelConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.quantized.qmodel import pack_model

__all__ = ["SHAPES", "shape_applicable", "make_train_step", "make_serve_step",
           "make_paged_serve_step", "make_paged_prefill_chunk_step",
           "make_page_copy_step", "make_prefill_step", "input_specs",
           "param_structs", "opt_structs", "qparam_structs", "cache_structs",
           "paged_pool_structs"]


SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

_SUBQUADRATIC = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.block_pattern in _SUBQUADRATIC
    return True


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    accum_steps: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_steps > 1`` microbatches the global batch (gradient accumulation
    via lax.scan): activation memory scales down ~accum_steps× at the cost of
    accum_steps weight passes — the standard fix when a train cell's peak
    memory exceeds HBM (e.g. zamba2-7b × train_4k, EXPERIMENTS.md §Dry-run).
    """
    schedule = cosine_schedule(opt_cfg)
    prefix = cfg.frontend_len if cfg.frontend == "vision" else 0

    def loss_of(p, mb):
        kw = {}
        if cfg.frontend == "vision":
            kw["vision_embeds"] = mb["vision_embeds"]
        if cfg.is_enc_dec:
            kw["enc_embeds"] = mb["enc_embeds"]
        tokens = mb["tokens"]
        logits = M.forward(p, cfg, tokens, **kw)
        if prefix:
            logits = logits[:, prefix:]
        return M.lm_loss(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), mbs)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        params2, opt2, metrics = adamw_update(params, grads, opt_state, opt_cfg, schedule)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """(params_q, tokens(B,1), cache, index) -> (next_token(B,1), cache)."""

    def serve_step(params_q, tokens, cache, index):
        logits, cache = M.decode_step(params_q, cfg, tokens, cache, index)
        V = logits.shape[-1]
        if V > cfg.vocab_size:
            logits = jnp.where(jnp.arange(V) < cfg.vocab_size, logits, -jnp.inf)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def make_paged_serve_step(cfg: ModelConfig):
    """(params_q, tokens(B,1), pools, block_tables(B,P), seq_lens(B))
    -> (next_token(B,1), pools) — the continuous-batching decode step
    (attention over the block-table page pool, per-sequence positions)."""
    from repro.serving.decode import make_paged_decode_step
    return make_paged_decode_step(cfg)


def make_paged_prefill_chunk_step(cfg: ModelConfig):
    """(params_q, tokens(1,C), pools, block_tables(1,P), offset())
    -> (logits(1,C,V), pools) — the chunked paged-prefill admit step (C a
    page multiple; one compiled program per chunk length, shared across
    admits)."""
    from repro.serving.prefill import make_paged_prefill_step
    return make_paged_prefill_step(cfg)


def make_page_copy_step(cfg: ModelConfig):
    """(pools, src(), dst()) -> pools with page ``dst`` <- page ``src`` on
    every leaf — the copy-on-write fork the batcher runs before a decode
    write would mutate a page that still has other owners (prefix-cache /
    duplicate-admit sharing). Page ids are traced scalars: ONE compiled
    program covers every fork."""
    from repro.serving.paged_cache import _copy_page
    return _copy_page


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """(params_q, batch) -> (last-token logits, cache)."""

    def prefill_step(params_q, batch):
        kw = {}
        if cfg.frontend == "vision":
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.is_enc_dec:
            kw["enc_embeds"] = batch["enc_embeds"]
        logits, cache = M.prefill(params_q, cfg, batch["tokens"], max_len, **kw)
        return logits[:, -1:], cache

    return prefill_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (no allocation — dry-run inputs)
# ---------------------------------------------------------------------------

def param_structs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(M.init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def opt_structs(cfg: ModelConfig):
    return jax.eval_shape(adamw_init, param_structs(cfg))


def qparam_structs(cfg: ModelConfig, qcfg: QuantConfig):
    """Packed-QTensor param tree as ShapeDtypeStructs (serving dry-run)."""
    def build():
        p = M.init_params(jax.random.PRNGKey(0), cfg)
        return pack_model(p, qcfg)
    return jax.eval_shape(build)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, max_len))


def paged_pool_structs(cfg: ModelConfig, n_pages: int, page_size: int):
    """Page-pool tree as ShapeDtypeStructs (paged serving dry-run inputs).

    Derived from ``PagedKVCache`` itself via eval_shape so the dry-run specs
    can never drift from the layout the batcher actually allocates.
    """
    from repro.serving.paged_cache import PagedKVCache

    def build():
        return PagedKVCache(cfg, n_pages=n_pages, page_size=page_size,
                            max_pages_per_seq=1).pools

    return jax.eval_shape(build)


def _token_struct(batch, seq):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str, qcfg: Optional[QuantConfig] = None):
    """Returns (step_kind, args_structs) for jit(...).lower(*args_structs)."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.compute_dtype)

    if info["kind"] == "train":
        batch = {"tokens": _token_struct(B, S)}
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model), dt)
        if cfg.is_enc_dec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return "train", (param_structs(cfg), opt_structs(cfg), batch)

    qcfg = qcfg or QuantConfig(bits=2, group_size=128)
    params_q = qparam_structs(cfg, qcfg)

    if info["kind"] == "prefill":
        batch = {"tokens": _token_struct(B, S)}
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model), dt)
        if cfg.is_enc_dec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return "prefill", (params_q, batch)

    # decode: 1 new token against a seq_len-deep cache
    cache = cache_structs(cfg, B, S)
    if cfg.is_enc_dec:
        # cross-attention cache from a prefilled encoder of length frontend_len
        enc_len = cfg.frontend_len or S
        hd = cfg.resolved_head_dim
        cross = (jax.ShapeDtypeStruct((cfg.n_layers, B, enc_len, cfg.n_kv_heads, hd), dt),
                 jax.ShapeDtypeStruct((cfg.n_layers, B, enc_len, cfg.n_kv_heads, hd), dt))
        cache = dict(cache)
        cache["cross"] = cross
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return "decode", (params_q, _token_struct(B, 1), cache, index)
