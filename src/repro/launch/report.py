"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report [--write]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

ARCH_ORDER = ["zamba2-7b", "internlm2-1.8b", "qwen3-4b", "command-r-35b",
              "yi-6b", "mamba2-2.7b", "internvl2-1b", "seamless-m4t-medium",
              "phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(include_opts: bool = False):
    cells = {}
    for p in ART.glob("*.json"):
        if "__opt-" in p.name and not include_opts:
            continue  # §Perf variants live beside the baselines
        r = json.loads(p.read_text())
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n / 2**30:.2f}"


def dryrun_table(cells):
    lines = [
        "| arch | shape | 16x16 | 2x16x16 | per-dev peak GiB | args GiB "
        "| collective schedule (per-device bytes, scan body x1) |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c1 = cells.get((a, s, "16x16"))
            c2 = cells.get((a, s, "2x16x16"))
            if c1 is None and c2 is None:
                continue
            if c1 and c1.get("skipped"):
                lines.append(f"| {a} | {s} | SKIP (full-attention; DESIGN.md) | SKIP | - | - | - |")
                continue
            ok1 = "PASS" if (c1 and c1.get("ok")) else "FAIL"
            ok2 = "PASS" if (c2 and c2.get("ok")) else "FAIL"
            mem = c1.get("memory") if c1 else None
            coll = c1.get("coll_schedule", {}) if c1 else {}
            coll_s = ", ".join(f"{k}:{v/2**20:.1f}MiB" for k, v in sorted(coll.items())) or "none"
            lines.append(
                f"| {a} | {s} | {ok1} | {ok2} | "
                f"{fmt_bytes(mem['peak_bytes']) if mem else '-'} | "
                f"{fmt_bytes(mem['argument_bytes']) if mem else '-'} | {coll_s} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| MODEL_FLOPs | useful ratio | roofline frac (mfu_bound) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, "16x16"))
            if c is None:
                continue
            if c.get("skipped"):
                lines.append(f"| {a} | {s} | - | - | - | skipped | - | - | - |")
                continue
            t = c.get("roofline")
            if not t:
                status = 'FAILED' if not c['ok'] else 'no-delta'
                lines.append(f"| {a} | {s} | ? | ? | ? | {status} | - | - | - |")
                continue
            lines.append(
                f"| {a} | {s} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
                f"{t['collective_s']:.3e} | **{t['dominant'][:-2]}** | "
                f"{t['model_flops_global']:.2e} | {t['useful_ratio']:.3f} | "
                f"{t['mfu_bound']:.4f} |")
    return "\n".join(lines)


def summary(cells):
    n_ok = sum(1 for c in cells.values() if c.get("ok") and not c.get("skipped"))
    n_skip = sum(1 for c in cells.values() if c.get("skipped"))
    n_fail = sum(1 for c in cells.values() if not c.get("ok"))
    return f"{len(cells)} cells: {n_ok} compiled PASS, {n_skip} skipped-by-design, {n_fail} FAIL"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"], default="both")
    args = ap.parse_args()
    cells = load_cells()
    print(summary(cells))
    if args.section in ("dryrun", "both"):
        print("\n### Dry-run matrix\n")
        print(dryrun_table(cells))
    if args.section in ("roofline", "both"):
        print("\n### Roofline (single-pod 16x16, per-device per-step seconds)\n")
        print(roofline_table(cells))


if __name__ == "__main__":
    main()
