"""Launchers: mesh construction, multi-pod dry-run, training, serving,
roofline analysis. NOTE: dryrun.py sets XLA_FLAGS at import — import it only
in a dedicated process."""
