"""Launch driver for the population × island discrete search.

    PYTHONPATH=src python -m repro.launch.search --arch opt-tiny \
        --steps 40 --population 4 --islands 2 --bits 2 --group 32

Builds the local mesh, shards the calibration batch over the data axis,
runs the RTN→InvarExplore pipeline through ``repro.search.engine``, and
merges a proposals/sec row into ``artifacts/benchmarks/BENCH_search.json``
so CI accumulates a search-perf trajectory next to ``BENCH_kernels.json``.
With ``--mapped`` the islands run one-per-device-shard
(``SearchConfig(mapped=True)``; ``--islands`` must equal the device count)
and the row lands under the ``search_mapped_islands/`` family — bench-smoke
asserts both families are present. With ``--measure-mem`` the row carries
``peak_live_bytes`` (the ``jax.live_arrays()`` delta over the run) and lands
under ``search_unit_install/`` or ``search_stack_install/`` per
``--install``, so CI can assert the O(unit) memory model at K=8.

Configs are run in their ``.reduced()`` form: this driver is the
CPU-container benchmark/smoke entry; the full-size configs are exercised
structurally by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import get_config
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig
from repro.data.calib import calibration_tokens
from repro.dist.sharding import ShardingRules, data_spec
from repro.launch.mesh import make_local_mesh
from repro.models import init_params

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "benchmarks"

__all__ = ["run_search_bench", "main"]


def _merge_rows(out: pathlib.Path, row: dict):
    """Accumulate rows by name (so the engine and mapped-islands benches land
    side by side in one BENCH_search.json across invocations)."""
    rows = []
    if out.exists():
        try:
            rows = [r for r in json.loads(out.read_text())
                    if r.get("name") != row["name"]]
        except (ValueError, KeyError):
            rows = []
    rows.append(row)
    out.write_text(json.dumps(rows, indent=1))


def run_search_bench(arch: str = "opt-tiny", *, steps: int = 40,
                     population: int = 4, islands: int = 1,
                     temperature: float = 0.0, anneal: str = "geometric",
                     migrate_every: int = 25, fused: bool = False,
                     mapped: bool = False, objective: str = "ce",
                     install: str = "unit", tabu: int = 0,
                     shard_calib: bool = False, measure_mem: bool = False,
                     bits: int = 2, group: int = 32, n_seqs: int = 4,
                     seq_len: int = 128, seed: int = 0,
                     out: pathlib.Path = None,
                     metrics_out: str = obs.DEFAULT_METRICS_PATH) -> dict:
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(seed), cfg)

    mesh = make_local_mesh()
    rules = ShardingRules(mesh, cfg)
    calib = jnp.asarray(calibration_tokens(cfg.vocab_size, n_seqs=n_seqs,
                                           seq_len=seq_len))
    if not mapped:  # mapped mode replicates the calib batch to every island
        calib = jax.device_put(calib, jax.sharding.NamedSharding(
            mesh, data_spec(rules, calib.shape[0])))

    scfg = SearchConfig(steps=steps, seed=seed, n_match_layers=2, log_every=0,
                        population=population, islands=islands,
                        temperature=temperature, anneal=anneal,
                        migrate_every=migrate_every, fused_kernel=fused,
                        mapped=mapped, objective=objective, install=install,
                        tabu=tabu, shard_calib=shard_calib,
                        measure_memory=measure_mem)
    qcfg = QuantConfig(bits=bits, group_size=group)

    prop_before = obs.counter(
        "search_proposals_total", "Candidate transforms proposed").total()
    t0 = time.monotonic()
    result = quantize_model(params, cfg, qcfg, method="rtn",
                            calib_tokens=calib, search=scfg)
    dt = time.monotonic() - t0
    sr = result.search
    proposals = sr.stats["proposals"] if sr.stats else steps
    # the registry must reconcile exactly with the legacy stats dict — a
    # drift here means an instrumentation hook was moved off the hot path
    prop_delta = obs.counter("search_proposals_total", "").total() - prop_before
    if sr.stats and not mapped and prop_delta != proposals:
        raise AssertionError(
            f"obs/stats divergence: search_proposals_total grew by "
            f"{prop_delta} but stats['proposals'] == {proposals}")
    if measure_mem:
        # memory-model benchmark rows: bench-smoke asserts the unit-install
        # peak live bytes stay below the K-full-stacks lane at the same K
        family = ("search_unit_install" if install == "unit"
                  else "search_stack_install")
    elif mapped:
        family = "search_mapped_islands"
    else:
        family = "search/engine"
    row = {
        "name": (f"{family}/{arch}s{steps}p{population}i{islands}"
                 f"b{bits}g{group}" + ("fused" if fused else "")
                 + (f"-{objective}" if objective != "ce" else "")),
        "us_per_call": round(dt * 1e6 / max(proposals, 1), 1),
        "derived": (f"proposals_per_sec={proposals / max(dt, 1e-9):.2f} "
                    f"loss={sr.initial_loss:.4f}->{sr.final_loss:.4f} "
                    f"accept={sr.accept_rate:.2%} "
                    f"migrations={sr.stats['migrations'] if sr.stats else 0} "
                    f"objective={sr.stats.get('objective', objective)} "
                    f"install={sr.stats.get('install', install)} "
                    f"tabu_hits={sr.stats.get('tabu_hits', 0)}"),
    }
    if measure_mem and sr.stats and "peak_live_bytes" in sr.stats:
        row["peak_live_bytes"] = int(sr.stats["peak_live_bytes"])
        row["stack_bytes"] = int(sr.stats["stack_bytes"])
        row["candidate_batch_bytes"] = int(sr.stats["candidate_batch_bytes"])
    print(f"{row['name']},{row['us_per_call']},{row['derived']}")
    out = pathlib.Path(out) if out else ART / "BENCH_search.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    _merge_rows(out, row)
    if metrics_out:
        obs.write_snapshot(path=metrics_out)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--population", type=int, default=4)
    ap.add_argument("--islands", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--anneal", default="geometric")
    ap.add_argument("--migrate-every", type=int, default=25)
    ap.add_argument("--fused", action="store_true",
                    help="fused transform+fake-quant kernel hot path")
    ap.add_argument("--mapped", action="store_true",
                    help="one island per mesh shard (requires --islands == "
                         "device count; see README 'Multi-host')")
    ap.add_argument("--objective", default="ce",
                    choices=["ce", "kl", "swd_actmatch", "saliency_ce"],
                    help="search objective (registry name)")
    ap.add_argument("--install", default="unit", choices=["unit", "stack"],
                    help="candidate install mode: 'unit' = stack + K x unit "
                         "dynamic-slice buffers; 'stack' = K full stacks")
    ap.add_argument("--tabu", type=int, default=0,
                    help="tried-point memory capacity (0 disables)")
    ap.add_argument("--shard-calib", action="store_true",
                    help="each island climbs on its own calibration slice")
    ap.add_argument("--measure-mem", action="store_true",
                    help="sample jax.live_arrays() peaks; rows land under "
                         "search_unit_install/search_stack_install")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--seqs", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--metrics-out", default=obs.DEFAULT_METRICS_PATH,
                    help="merged metrics snapshot path ('' disables)")
    ap.add_argument("--trace-out", default="",
                    help="span/event JSONL sink path ('' disables)")
    args = ap.parse_args(argv)
    if args.trace_out:
        obs.set_trace_sink(args.trace_out)
    run_search_bench(args.arch, steps=args.steps, population=args.population,
                     islands=args.islands, temperature=args.temperature,
                     anneal=args.anneal, migrate_every=args.migrate_every,
                     fused=args.fused, mapped=args.mapped,
                     objective=args.objective, install=args.install,
                     tabu=args.tabu, shard_calib=args.shard_calib,
                     measure_mem=args.measure_mem, bits=args.bits,
                     group=args.group, n_seqs=args.seqs,
                     seq_len=args.seq_len, seed=args.seed, out=args.out,
                     metrics_out=args.metrics_out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
