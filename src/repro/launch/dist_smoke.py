"""Multi-host smoke: real ``jax.distributed`` bring-up, mapped-island parity,
sharded-checkpoint re-mesh. The CI ``distributed`` lane runs TWO of these as
real OS processes against one localhost coordinator:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
    PYTHONPATH=src python -m repro.launch.dist_smoke \\
        --coordinator 127.0.0.1:12355 --num-processes 2 --process-id 0 \\
        --ckpt-dir /tmp/dist_ckpt &
    ... same with --process-id 1 ...

Each process asserts, and exits non-zero on any failure:

  1. bring-up: ``dist.runtime.initialize`` + a psum ``barrier()`` across all
     global devices (2 procs x 2 forced CPU devices = 4);
  2. mapped-island parity: a small ``mapped=True`` search over the 4-shard
     global mesh must reproduce the sequential engine's trajectory
     BIT-FOR-BIT (histories compared exactly — the sequential run is pure
     process-local compute, so it doubles as the single-process reference);
  3. obs aggregation: each process fills a registry with pid-skewed values;
     ``obs.dist_snapshot()`` must merge them (counters summed, gauges
     min/max/sum, histogram buckets added) into byte-identical snapshots on
     every host, with process 0 writing the merged report;
  4. sharded checkpoint: a tree (dense + QTensor leaves) sharded over a
     ("data", "model") mesh is saved with each process writing ONLY its
     addressable shards, then restored onto a DIFFERENT mesh shape (1-D
     ("data",)) and onto plain host-local arrays; both must match the
     original values exactly.

``--num-processes 1`` (the default) runs the same checks single-process on
however many local devices exist — that is what ``tests/test_dist_smoke.py``
drives under a forced 2-device CPU topology.
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def _check_mapped_parity(steps: int, migrate_every: int, population: int):
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.quant import QuantConfig
    from repro.core.search import SearchConfig
    from repro.models import init_params
    from repro.search import run as run_search

    cfg = get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=4, max_seq_len=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                               cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=32)
    n_islands = jax.device_count()
    scfg = SearchConfig(steps=steps, seed=0, n_match_layers=2, log_every=0,
                        islands=n_islands, migrate_every=migrate_every,
                        population=population)

    r_seq = run_search(params, params, cfg, qcfg, calib, scfg)
    r_map = run_search(params, params, cfg, qcfg, calib,
                       dataclasses.replace(scfg, mapped=True))
    if r_seq.island_histories != r_map.island_histories:
        for i, (a, b) in enumerate(zip(r_seq.island_histories,
                                       r_map.island_histories)):
            for ea, eb in zip(a, b):
                if ea != eb:
                    raise AssertionError(
                        f"mapped-island divergence at island {i}: "
                        f"sequential {ea} vs mapped {eb}")
        raise AssertionError("mapped-island histories differ in length")
    assert r_seq.final_loss == r_map.final_loss
    assert r_seq.stats["migrations"] == r_map.stats["migrations"]
    import numpy as np
    np.testing.assert_array_equal(np.asarray(r_seq.transforms.pi),
                                  np.asarray(r_map.transforms.pi))
    print(f"[dist_smoke] mapped parity OK: {n_islands} islands x "
          f"{steps} steps, {r_map.stats['migrations']} migrations, "
          f"loss {r_map.initial_loss:.4f}->{r_map.final_loss:.4f}",
          flush=True)


def _check_obs_aggregation(metrics_out: str = None):
    """Multi-host metric aggregation: every process contributes a pid-skewed
    registry; ``dist_snapshot()`` must produce the SAME merged snapshot on
    every host, with counters summed, gauges min/max/sum-merged and
    histogram buckets added exactly. Process 0 commits the report."""
    import json

    import jax

    from repro import obs

    pid, nproc = jax.process_index(), jax.process_count()
    reg = obs.Registry()
    # pid-dependent values so a "merge" that is secretly a local snapshot
    # (or that double-counts a host) cannot pass the sum checks
    reg.counter("smoke_widgets_total", "per-host counter").inc(10 + pid)
    reg.counter("smoke_labelled_total", "labelled counter").inc(
        2, host=f"h{pid}")
    reg.gauge("smoke_depth", "per-host gauge").set(float(pid))
    h = reg.histogram("smoke_lat_seconds", "per-host histogram",
                      buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5 + pid)     # pid 0 lands in bucket 1, pid>=1 in +Inf

    snap = obs.dist_snapshot(reg, force_gather=(nproc == 1))
    blob = obs.snapshot_json(snap)

    want_widgets = sum(10 + p for p in range(nproc))
    got_widgets = snap["smoke_widgets_total"]["series"][0]["value"]
    assert got_widgets == want_widgets, \
        f"counter merge: {got_widgets} != {want_widgets}"
    assert len(snap["smoke_labelled_total"]["series"]) == nproc, \
        "labelled series lost in the merge"
    g = snap["smoke_depth"]["series"][0]
    assert (g["min"], g["max"], g["n"]) == (0.0, float(nproc - 1), nproc), \
        f"gauge merge: {g}"
    hs = snap["smoke_lat_seconds"]["series"][0]
    assert hs["count"] == 2 * nproc and hs["counts"][0] == nproc, \
        f"histogram merge: {hs}"

    # cross-host identity: all-gather each host's JSON of the MERGED snapshot
    # and require byte equality (single-process: trivially one payload)
    from repro.obs.aggregate import _exchange_payload
    peers = set(_exchange_payload(blob.encode()))
    assert len(peers) == 1, "merged snapshots differ across hosts"

    if metrics_out:
        p = obs.write_snapshot(snap, path=metrics_out)
        if p is not None:   # process 0 only
            back = json.loads(p.read_text())
            assert back["smoke_widgets_total"]["series"][0]["value"] == \
                want_widgets
    print(f"[dist_smoke] obs aggregation OK: {nproc} process(es), "
          f"widgets={int(got_widgets)}, identical snapshots on all hosts",
          flush=True)


def _check_sharded_ckpt(ckpt_dir: str):
    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.ckpt.checkpoint import (restore_sharded_checkpoint,
                                       save_sharded_checkpoint)
    from repro.core.quant import QTensor, QuantConfig, quantize_tensor
    from repro.dist import runtime

    devs = np.array(jax.devices())
    n = len(devs)
    if n % 2 == 0 and n >= 4:
        save_mesh = Mesh(devs.reshape(2, n // 2), ("data", "model"))
        w_spec = P("data", "model")
        qt_spec = P(None, "model")
    else:
        save_mesh = Mesh(devs, ("data",))
        w_spec = P("data", None)
        qt_spec = P(None, "data")
    load_mesh = Mesh(devs, ("data",))

    rng = np.random.default_rng(7)
    w_full = rng.normal(size=(8, 16)).astype(np.float32)
    qt_src = rng.normal(size=(64, 8)).astype(np.float32)
    qt = quantize_tensor(jax.numpy.asarray(qt_src),
                         QuantConfig(bits=2, group_size=32))
    qt_full = jax.tree.map(np.asarray, qt)
    tree = {
        "w": runtime.global_put(w_full, NamedSharding(save_mesh, w_spec)),
        "qt": jax.tree.map(
            lambda x: runtime.global_put(
                np.asarray(x), NamedSharding(save_mesh, qt_spec)), qt),
        "t": (runtime.global_put(np.arange(n, dtype=np.float32),
                                 NamedSharding(save_mesh, P("data"))), None),
    }
    save_sharded_checkpoint(ckpt_dir, 1, tree)
    runtime.barrier("ckpt-saved")

    def verify_shards(arr, full):
        for s in arr.addressable_shards:
            np.testing.assert_array_equal(np.asarray(s.data), full[s.index])

    # re-mesh: restore onto the 1-D ("data",) mesh
    shardings = {
        "w": NamedSharding(load_mesh, P("data", None)),
        "qt": QTensor(NamedSharding(load_mesh, P(None, "data")),
                      NamedSharding(load_mesh, P(None, "data")),
                      NamedSharding(load_mesh, P(None, "data")),
                      qt.bits, qt.group_size, qt.shape),
        "t": (NamedSharding(load_mesh, P("data")), None),
    }
    restored, manifest = restore_sharded_checkpoint(ckpt_dir, 1, shardings)
    assert manifest["step"] == 1 and manifest["format"] == 2
    verify_shards(restored["w"], w_full)
    verify_shards(restored["qt"].packed, qt_full.packed)
    verify_shards(restored["qt"].scale, qt_full.scale)
    verify_shards(restored["t"][0], np.arange(n, dtype=np.float32))
    assert restored["t"][1] is None

    # degenerate re-mesh: plain host-local arrays
    local, _ = restore_sharded_checkpoint(ckpt_dir, 1, None)
    np.testing.assert_array_equal(np.asarray(local["w"]), w_full)
    np.testing.assert_array_equal(np.asarray(local["qt"].packed),
                                  qt_full.packed)
    runtime.barrier("ckpt-restored")
    print(f"[dist_smoke] sharded ckpt OK: saved on {save_mesh.shape}, "
          f"restored onto {load_mesh.shape} + host-local", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (e.g. 127.0.0.1:12355)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--migrate-every", type=int, default=2)
    ap.add_argument("--population", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None,
                    help="SHARED directory for the sharded-checkpoint phase "
                         "(all processes must see the same files)")
    ap.add_argument("--metrics-out", default=None,
                    help="merged metrics snapshot path for the obs phase "
                         "(process 0 writes; default: no file)")
    args = ap.parse_args(argv)

    # must precede any jax computation (CPU collectives backend selection)
    from repro.dist import runtime
    runtime.initialize(args.coordinator, args.num_processes, args.process_id)

    import jax  # noqa: E402  (backend comes up here, after initialize)
    summary = runtime.device_summary()
    print(f"[dist_smoke] {summary}", flush=True)
    if args.num_processes > 1:
        assert jax.process_count() == args.num_processes, \
            f"expected {args.num_processes} processes, got {jax.process_count()}"
    runtime.barrier("bring-up")
    print(f"[dist_smoke] barrier OK across {jax.device_count()} devices",
          flush=True)

    _check_mapped_parity(args.steps, args.migrate_every, args.population)

    _check_obs_aggregation(args.metrics_out)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="dist_smoke_ckpt_")
    _check_sharded_ckpt(ckpt_dir)

    print(f"DIST_SMOKE_OK process={jax.process_index()}/"
          f"{jax.process_count()}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
