"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.

Mesh creation goes through ``repro.dist.compat.make_mesh``: the
``axis_types=`` kwarg only exists on newer jax (older releases treat every
axis as Auto, which is what we want on both).
"""
from __future__ import annotations

import jax

from repro.dist.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: single pod (16, 16) = 256 chips as ("data", "model"); two pods
    (2, 16, 16) = 512 chips with the leading "pod" axis crossing DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types="auto")


def make_local_mesh():
    """Whatever devices exist locally, as a 1D ("data",) mesh (tests/examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",), axis_types="auto")
