"""Batched serving driver for quantized models.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-tiny --bits 2

Request flow: batched prompts -> prefill (builds KV cache) -> greedy decode
loop with the packed-QTensor weights (dequant-on-the-fly in each scan body;
on TPU the fused quant_matmul kernel serves the same role at the block level).
A minimal continuous-batching queue is included: finished sequences are
replaced by queued requests between decode steps.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.steps import make_serve_step
from repro.models import init_params, prefill
from repro.quantized.qmodel import pack_model, packed_bytes, dense_bytes


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new: int = 32
    out: Optional[list] = None


class BatchedServer:
    """Fixed-batch greedy decoding server with slot recycling."""

    def __init__(self, params_q, cfg, batch_size: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params_q
        self.B = batch_size
        self.max_len = max_len
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.prefill_fn = jax.jit(
            lambda p, toks: prefill(p, cfg, toks, max_len))

    def generate(self, requests: List[Request]):
        """Serve all requests; returns list of generated-token lists."""
        queue = list(requests)
        results = {id(r): [] for r in requests}
        while queue:
            chunk = queue[: self.B]
            queue = queue[self.B:]
            # pad the batch to B with copies (masked out of results)
            live = len(chunk)
            while len(chunk) < self.B:
                chunk.append(chunk[-1])
            plen = max(len(r.prompt) for r in chunk)
            toks = np.stack([np.pad(r.prompt, (plen - len(r.prompt), 0),
                                    constant_values=0) for r in chunk]).astype(np.int32)
            logits, cache = self.prefill_fn(self.params, jnp.asarray(toks))
            last = jnp.argmax(logits[:, -1, : self.cfg.vocab_size], axis=-1
                              ).astype(jnp.int32)[:, None]
            index = jnp.int32(plen)
            max_new = max(r.max_new for r in chunk[:live])
            outs = [last]
            tok = last
            for t in range(max_new - 1):
                tok, cache = self.step_fn(self.params, tok, cache, index + t)
                outs.append(tok)
            gen = jnp.concatenate(outs, axis=1)
            for i, r in enumerate(chunk[:live]):
                results[id(r)] = np.asarray(gen[i, : r.max_new]).tolist()
        return [results[id(r)] for r in requests]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    qcfg = QuantConfig(bits=args.bits, group_size=args.group)
    params_q = pack_model(params, qcfg)
    pb, db = packed_bytes(params_q), dense_bytes(params_q)
    print(f"[serve] packed={pb/1e6:.2f}MB vs fp16={db/1e6:.2f}MB "
          f"({db/pb:.1f}x smaller)")

    server = BatchedServer(params_q, cfg, batch_size=args.batch)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    outs = server.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o[:10]}...")


if __name__ == "__main__":
    main()
