"""Serving driver for quantized models: paged KV cache + continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-tiny --bits 2

Default flow (``PagedServer``): requests stream through
``repro.serving.ContinuousBatcher`` — per-request prefill scatters K/V into a
fixed-size page pool, one jitted decode step advances every live sequence at
its own depth (attention reads pages through the block-table Pallas kernel),
finished sequences hand their page references back between steps, and
exhaustion preempts the scheduler's victim (FIFO: the newest sequence).
Pages are refcounted and content-addressed: shared prompt prefixes are
aliased from the prefix cache at admit instead of re-prefilled
(``--no-prefix-cache`` disables), and ``--scheduler slo`` turns on priority
admission with per-tenant page quotas (``--tenant-quota``). Weights stay
packed QTensors throughout (dequant-on-the-fly in each scan body; the fused
quant_matmul kernel on TPU).

``BatchedServer`` (``--legacy``) keeps the old fixed-slot recycling loop for
comparison: it pads every batch to the longest member and holds max_len-deep
cache slots whether used or not.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.steps import make_serve_step
from repro.models import init_params, prefill
from repro.quantized.qmodel import pack_model, packed_bytes, dense_bytes
from repro.serving import (ContinuousBatcher, PagedKVCache, PagedRequest,
                           make_scheduler)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new: int = 32
    out: Optional[list] = None
    temperature: float = 0.0        # <= 0: greedy (paged server only)
    top_k: int = 0                  # 0: unrestricted
    seed: int = 0                   # per-request sample stream
    tenant: str = "default"         # quota bucket (SLO scheduler)
    priority: int = 0               # admission order (SLO scheduler)


class BatchedServer:
    """Fixed-batch greedy decoding server with slot recycling."""

    def __init__(self, params_q, cfg, batch_size: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params_q
        self.B = batch_size
        self.max_len = max_len
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.prefill_fn = jax.jit(
            lambda p, toks: prefill(p, cfg, toks, max_len))

    def generate(self, requests: List[Request]):
        """Serve all requests; returns list of generated-token lists."""
        queue = list(requests)
        results = {id(r): [] for r in requests}
        while queue:
            chunk = queue[: self.B]
            queue = queue[self.B:]
            # pad the batch to B with copies (masked out of results)
            live = len(chunk)
            while len(chunk) < self.B:
                chunk.append(chunk[-1])
            plen = max(len(r.prompt) for r in chunk)
            toks = np.stack([np.pad(r.prompt, (plen - len(r.prompt), 0),
                                    constant_values=0) for r in chunk]).astype(np.int32)
            logits, cache = self.prefill_fn(self.params, jnp.asarray(toks))
            last = jnp.argmax(logits[:, -1, : self.cfg.vocab_size], axis=-1
                              ).astype(jnp.int32)[:, None]
            index = jnp.int32(plen)
            max_new = max(r.max_new for r in chunk[:live])
            outs = [last]
            tok = last
            for t in range(max_new - 1):
                tok, cache = self.step_fn(self.params, tok, cache, index + t)
                outs.append(tok)
            gen = jnp.concatenate(outs, axis=1)
            for i, r in enumerate(chunk[:live]):
                results[id(r)] = np.asarray(gen[i, : r.max_new]).tolist()
        return [results[id(r)] for r in requests]


class PagedServer:
    """Continuous-batching server over the paged KV cache.

    ``n_pages`` bounds TOTAL cache memory across all live sequences (the
    dense server's cost was batch x max_len whether used or not);
    ``max_pages_per_seq`` bounds a single sequence. Accepts the same
    ``Request`` objects as ``BatchedServer``.

    ``prefix_cache`` (default on) shares pages between requests: full-page
    prompt runs already in the pool are aliased at admit (zero prefill) and
    identical in-flight requests decode from one copy (COW-forked at the
    first diverging write) — outputs stay token-identical to sharing
    disabled. ``scheduler`` picks the admission/eviction policy: ``"fifo"``
    (legacy-identical default), ``"slo"`` (uses ``Request.tenant`` /
    ``priority`` with ``tenant_quota`` pages per tenant), or any
    ``serving.Scheduler`` instance.
    """

    def __init__(self, params_q, cfg, max_batch: int = 4, page_size: int = 16,
                 n_pages: Optional[int] = None, max_len: int = 512,
                 use_pallas: bool = True, prefill_chunk_pages: int = 4,
                 prefix_cache: bool = True, scheduler="fifo",
                 tenant_quota: Optional[int] = None,
                 gqa_pages_per_block: int = 1):
        pages_per_seq = -(-max_len // page_size)
        if n_pages is None:
            n_pages = max_batch * pages_per_seq + 1  # +1 null page
        self.cfg = cfg
        self.cache = PagedKVCache(cfg, n_pages=n_pages, page_size=page_size,
                                  max_pages_per_seq=pages_per_seq)
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, tenant_quota=tenant_quota)
        self.batcher = ContinuousBatcher(params_q, cfg, self.cache,
                                         max_batch=max_batch,
                                         use_pallas=use_pallas,
                                         prefill_chunk_pages=prefill_chunk_pages,
                                         scheduler=scheduler,
                                         prefix_cache=prefix_cache,
                                         gqa_pages_per_block=gqa_pages_per_block)

    def generate(self, requests: List[Request]):
        paged = [PagedRequest(prompt=np.asarray(r.prompt, np.int32),
                              max_new=r.max_new, temperature=r.temperature,
                              top_k=r.top_k, seed=r.seed, tenant=r.tenant,
                              priority=r.priority) for r in requests]
        return self.batcher.run(paged)

    def sharing_report(self) -> dict:
        """Prefix-sharing + latency stats for the run(s) so far.

        TTFT percentiles come from the ``serving_ttft_seconds`` histogram
        (accurate to within one bucket width; exact under multi-host merge),
        not a per-request list."""
        st = self.batcher.stats
        total = st["prefill_tokens"] + st["prefill_tokens_saved"]
        ttft = self.batcher.obs["ttft"]

        def pct(p):
            return ttft.quantile(p) if ttft.count() else 0.0

        return {
            "prefill_tokens": st["prefill_tokens"],
            "prefill_tokens_saved": st["prefill_tokens_saved"],
            "saved_frac": st["prefill_tokens_saved"] / total if total else 0.0,
            "aliased_pages": st["aliased_pages"],
            "dedup_admits": st["dedup_admits"],
            "cow_forks": st["cow_forks"],
            "ttft_p50_s": pct(0.50),
            "ttft_p99_s": pct(0.99),
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=None,
                    help="total page-pool size (default: batch x max_len/page)")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk-pages", type=int, default=4,
                    help="pages per paged-prefill chunk (admit granularity)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix-cache page sharing across requests")
    ap.add_argument("--scheduler", default="fifo", choices=("fifo", "slo"),
                    help="admission/eviction policy (slo: priority + quotas)")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max pages a tenant's live requests may hold (slo)")
    ap.add_argument("--gqa-pages-per-block", type=int, default=1,
                    help="pages staged per fused-GQA decode block (1 keeps "
                         "the single-page grid bit-for-bit)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests round-robin over N tenants, each "
                         "sharing one system-prompt prefix")
    ap.add_argument("--legacy", action="store_true",
                    help="fixed-slot BatchedServer instead of the paged path")
    ap.add_argument("--metrics-out", default=obs.DEFAULT_METRICS_PATH,
                    help="merged metrics snapshot path ('' disables)")
    ap.add_argument("--trace-out", default="",
                    help="span/event JSONL sink path ('' disables)")
    args = ap.parse_args()
    if args.trace_out:
        obs.set_trace_sink(args.trace_out)

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    qcfg = QuantConfig(bits=args.bits, group_size=args.group)
    params_q = pack_model(params, qcfg)
    pb, db = packed_bytes(params_q), dense_bytes(params_q)
    print(f"[serve] packed={pb/1e6:.2f}MB vs fp16={db/1e6:.2f}MB "
          f"({db/pb:.1f}x smaller)")

    rng = np.random.default_rng(0)
    # a shared system prompt (two pages) in front of every request makes the
    # prefix cache visible in the default run; --tenants > 1 adds a shorter
    # per-tenant template on top (the many-tenant trace shape)
    sys_prompt = rng.integers(0, cfg.vocab_size,
                              size=2 * args.page_size).astype(np.int32)
    tenant_tpl = {t: rng.integers(0, cfg.vocab_size,
                                  size=args.page_size).astype(np.int32)
                  for t in range(args.tenants)}
    reqs = []
    for i in range(args.requests):
        t = i % args.tenants
        tail = rng.integers(0, cfg.vocab_size,
                            size=rng.integers(4, 12)).astype(np.int32)
        reqs.append(Request(
            prompt=np.concatenate([sys_prompt, tenant_tpl[t], tail]),
            max_new=args.max_new, temperature=args.temperature,
            top_k=args.top_k, seed=i, tenant=f"tenant{t}",
            priority=t % 3))
    if args.legacy:
        server = BatchedServer(params_q, cfg, batch_size=args.batch,
                               max_len=args.max_len)
    else:
        server = PagedServer(params_q, cfg, max_batch=args.batch,
                             page_size=args.page_size, n_pages=args.pages,
                             max_len=args.max_len,
                             prefill_chunk_pages=args.prefill_chunk_pages,
                             prefix_cache=not args.no_prefix_cache,
                             scheduler=args.scheduler,
                             tenant_quota=args.tenant_quota,
                             gqa_pages_per_block=args.gqa_pages_per_block)
        pool = server.cache.pool_bytes()
        dense = server.cache.dense_equiv_bytes(args.batch, args.max_len)
        print(f"[serve] page pool: {server.cache.n_pages} x "
              f"{args.page_size}-token pages = {pool/1e6:.2f}MB "
              f"(contiguous {args.batch}x{args.max_len} cache: {dense/1e6:.2f}MB)")
    t0 = time.monotonic()
    outs = server.generate(reqs)
    dt = time.monotonic() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    if not args.legacy:
        print(f"[serve] batcher stats: {server.batcher.stats}")
        rep = server.sharing_report()
        print(f"[serve] sharing: {rep['prefill_tokens_saved']} prompt tokens "
              f"aliased ({rep['saved_frac']:.0%} of prefill), "
              f"{rep['dedup_admits']} duplicate admits, "
              f"{rep['cow_forks']} COW forks; "
              f"TTFT p50={rep['ttft_p50_s']*1e3:.1f}ms "
              f"p99={rep['ttft_p99_s']*1e3:.1f}ms")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o[:10]}...")
    if args.metrics_out:
        p = obs.write_snapshot(path=args.metrics_out)
        print(f"[serve] metrics snapshot -> {p}")


if __name__ == "__main__":
    main()
