"""Calibration-set extraction (paper §4.1: 32 sequences × 512 tokens from the
Pile; here: deterministic sequences from the training source so the benchmark
models are calibrated in-distribution, like the paper's setup)."""
from __future__ import annotations

import numpy as np

from repro.data.pipeline import DataConfig, make_pipeline

__all__ = ["calibration_tokens", "shard_calibration"]


def calibration_tokens(vocab_size: int, n_seqs: int = 32, seq_len: int = 512,
                       seed: int = 99, source=None) -> np.ndarray:
    cfg = DataConfig(seq_len=seq_len, global_batch=n_seqs, seed=seed,
                     vocab_size=vocab_size)
    batch_at = make_pipeline(cfg, source=source)
    return batch_at(0)


def shard_calibration(calib, n_islands: int):
    """Per-island calibration slices (``SearchConfig(shard_calib=True)``):
    contiguous equal batch slices, one per island, so each chain climbs on
    its own data and islands exchange only objective estimates at migration.

    ``n_islands == 1`` returns ``[calib]`` unchanged — the sharded lane is
    then the replicated lane bit-for-bit (pinned by tests/test_search_v2.py).
    Requires the batch to divide evenly: a ragged split would hand islands
    different-shaped jitted programs AND different-sized loss estimates,
    silently biasing migration races.
    """
    n = int(n_islands)
    if n <= 1:
        return [calib]
    B = int(calib.shape[0])
    if B % n != 0:
        raise ValueError(
            f"shard_calib needs the calibration batch ({B} seqs) to divide "
            f"evenly over {n} islands; pad or trim the batch")
    per = B // n
    return [calib[i * per:(i + 1) * per] for i in range(n)]
