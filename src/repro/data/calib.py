"""Calibration-set extraction (paper §4.1: 32 sequences × 512 tokens from the
Pile; here: deterministic sequences from the training source so the benchmark
models are calibrated in-distribution, like the paper's setup)."""
from __future__ import annotations

import numpy as np

from repro.data.pipeline import DataConfig, make_pipeline

__all__ = ["calibration_tokens"]


def calibration_tokens(vocab_size: int, n_seqs: int = 32, seq_len: int = 512,
                       seed: int = 99, source=None) -> np.ndarray:
    cfg = DataConfig(seq_len=seq_len, global_batch=n_seqs, seed=seed,
                     vocab_size=vocab_size)
    batch_at = make_pipeline(cfg, source=source)
    return batch_at(0)
