"""Byte-level tokenizer (no external vocab files — fully offline)."""
from __future__ import annotations


__all__ = ["ByteTokenizer"]


class ByteTokenizer:
    """ids 0..255 = bytes; 256 = BOS; 257 = EOS; 258 = PAD."""

    BOS = 256
    EOS = 257
    PAD = 258
    vocab_size = 259

    def encode(self, text: str, add_special: bool = True):
        ids = list(text.encode("utf-8"))
        return ([self.BOS] + ids + [self.EOS]) if add_special else ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")
