from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import DataConfig, SyntheticZipf, TokenDataset, make_pipeline
from repro.data.calib import calibration_tokens

__all__ = ["ByteTokenizer", "DataConfig", "SyntheticZipf", "TokenDataset",
           "make_pipeline", "calibration_tokens"]
