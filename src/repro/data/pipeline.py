"""Deterministic, stateless-indexable data pipeline.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step, host_id) — a restarted/re-elected worker reproduces exactly the
batches it would have seen, so checkpoint-restart never replays or skips data
(DESIGN.md §5 straggler/elasticity notes).

Two sources:
  - SyntheticZipf: a deterministic Zipf-bigram "language" with enough
    structure for a small LM to learn (used by benchmarks; no network).
  - TokenDataset: any pre-tokenized flat array.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticZipf", "TokenDataset", "DataConfig", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 1234
    vocab_size: int = 512
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticZipf:
    """Deterministic Zipf-weighted bigram process.

    A fixed random bigram transition table (sparse, peaked) over the vocab
    gives the sequence real statistical structure: a trained LM reaches much
    lower CE than unigram entropy, and quantization visibly degrades it —
    which is what the paper's tables measure.
    """

    def __init__(self, vocab_size: int, seed: int = 7, branching: int = 8):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each token transitions to `branching` successors with Zipf weights
        self.next_tokens = rng.integers(0, vocab_size, size=(vocab_size, branching))
        w = 1.0 / np.arange(1, branching + 1)
        self.next_probs = w / w.sum()

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = rng.choice(self.vocab, p=self.unigram)
        for i in range(length):
            out[i] = tok
            if rng.random() < 0.1:  # occasional unigram reset
                tok = rng.choice(self.vocab, p=self.unigram)
            else:
                tok = self.next_tokens[tok, rng.choice(len(self.next_probs),
                                                       p=self.next_probs)]
        return out


class TokenDataset:
    """Flat pre-tokenized corpus, chunked into sequences."""

    def __init__(self, tokens: np.ndarray):
        self.tokens = np.asarray(tokens, np.int64)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        start = rng.integers(0, max(len(self.tokens) - length, 1))
        out = self.tokens[start:start + length]
        if len(out) < length:
            out = np.pad(out, (0, length - len(out)))
        return out


def make_pipeline(cfg: DataConfig, source=None):
    """Returns batch_at(step) -> (host_batch, seq_len) int32."""
    source = source or SyntheticZipf(cfg.vocab_size)

    def batch_at(step: int) -> np.ndarray:
        rows = []
        for b in range(cfg.host_batch):
            # unique, reproducible stream per (step, global row)
            grow = cfg.host_id * cfg.host_batch + b
            rng = np.random.default_rng((cfg.seed, step, grow))
            rows.append(source.sample(rng, cfg.seq_len))
        return np.stack(rows).astype(np.int32)

    return batch_at
