"""Core of the static-analysis pass: findings, checker registry, baseline.

The pass is an AST-plus-abstract-eval framework, not a style linter: every
checker guards an *invariant the test suite cannot see* — jit purity, PRNG
key discipline, monotonic-clock durations, Pallas VMEM budgets, metrics
registry hygiene. Checkers come in two shapes:

- per-file: ``check_file(SourceFile)`` walks one module's AST;
- project: ``check_project(files)`` sees every scanned file at once (needed
  for cross-file invariants like "one metric name, one kind") and may
  abstract-eval real code (the Pallas budget checker runs ``jax.eval_shape``
  over the config zoo).

Findings are identified for baseline purposes by (rule, path, symbol,
message) — NOT by line number — so unrelated edits above a known finding do
not churn the committed baseline. The baseline file gives the pass
fail-on-new semantics: ``python -m repro.analysis src`` exits non-zero only
for findings that are neither suppressed in-line nor recorded in the
baseline.

Suppression: append ``# analysis: ignore[rule]`` (or a bare
``# analysis: ignore`` to silence every rule) to the finding's anchor line.
``# analysis: skip-file`` within the first ten lines skips the whole module.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from collections import Counter as _Counter
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "SourceFile", "Checker", "CHECKERS", "register",
           "collect_files", "run_analysis", "AnalysisReport",
           "load_baseline", "save_baseline", "diff_against_baseline",
           "BASELINE_VERSION", "DEFAULT_BASELINE"]

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis_baseline.json"

_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[([A-Za-z0-9_,\- ]+)\])?")
_SKIP_FILE_RE = re.compile(r"#\s*analysis:\s*skip-file")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One violation. ``symbol`` is the enclosing def/class qualname (or ""),
    part of the baseline identity so findings survive line churn."""

    rule: str
    path: str                    # posix path relative to the scan root
    line: int
    message: str
    symbol: str = ""

    @property
    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "symbol": self.symbol, "message": self.message}

    @staticmethod
    def from_json(d: dict) -> "Finding":
        return Finding(rule=d["rule"], path=d["path"],
                       line=int(d.get("line", 0)),
                       message=d["message"], symbol=d.get("symbol", ""))


class SourceFile:
    """A parsed module plus the per-line suppression map."""

    def __init__(self, path: pathlib.Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:
            self.parse_error = e
        self.skip = any(_SKIP_FILE_RE.search(ln) for ln in self.lines[:10])
        # line -> set of suppressed rule names ("*" = all)
        self.suppressed: Dict[int, set] = {}
        for i, ln in enumerate(self.lines, 1):
            m = _IGNORE_RE.search(ln)
            if m:
                rules = ({r.strip() for r in m.group(1).split(",")}
                         if m.group(1) else {"*"})
                self.suppressed.setdefault(i, set()).update(rules)
        self._symbols: Optional[Dict[int, str]] = None

    def is_suppressed(self, finding: Finding) -> bool:
        rules = self.suppressed.get(finding.line)
        return bool(rules) and ("*" in rules or finding.rule in rules)

    def symbol_at(self, line: int) -> str:
        """Qualname of the innermost def/class containing ``line``."""
        if self._symbols is None:
            spans: List[Tuple[int, int, str]] = []

            def walk(node, prefix):
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef)):
                        qual = f"{prefix}{child.name}"
                        end = getattr(child, "end_lineno", child.lineno)
                        spans.append((child.lineno, end, qual))
                        walk(child, qual + ".")
                    else:
                        walk(child, prefix)

            if self.tree is not None:
                walk(self.tree, "")
            self._symbols = {}
            # innermost wins: apply wider spans first
            for lo, hi, qual in sorted(spans, key=lambda s: -(s[1] - s[0])):
                for ln in range(lo, hi + 1):
                    self._symbols[ln] = qual
        return self._symbols.get(line, "")


class Checker:
    """Base class. Subclasses set ``name``/``description``/``bug_class`` and
    override ``check_file`` and/or ``check_project``."""

    name: str = "abstract"
    description: str = ""
    bug_class: str = ""

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        return ()

    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        return ()


CHECKERS: Dict[str, Checker] = {}


def register(cls):
    """Class decorator: instantiate and add to the registry."""
    inst = cls()
    if inst.name in CHECKERS:
        raise ValueError(f"duplicate checker name {inst.name!r}")
    CHECKERS[inst.name] = inst
    return cls


def _load_default_checkers() -> None:
    """Import the shipped checker modules (idempotent)."""
    from repro.analysis import (clocks, metrics_hygiene,  # noqa: F401
                                pallas_budget, prng, purity)


def collect_files(paths: Sequence[str],
                  root: Optional[pathlib.Path] = None) -> List[SourceFile]:
    """Expand files/directories into SourceFiles with root-relative names."""
    root = pathlib.Path(root or pathlib.Path.cwd()).resolve()
    seen = {}
    for p in paths:
        p = pathlib.Path(p)
        candidates = (sorted(p.rglob("*.py")) if p.is_dir() else [p])
        for c in candidates:
            c = c.resolve()
            if "__pycache__" in c.parts or c in seen:
                continue
            try:
                rel = c.relative_to(root).as_posix()
            except ValueError:
                rel = c.as_posix()
            seen[c] = SourceFile(c, rel, c.read_text())
    return list(seen.values())


@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]              # kept (not suppressed), sorted
    suppressed: List[Finding]            # silenced by inline comments
    files: List[str]
    checkers: List[str]
    new: List[Finding] = dataclasses.field(default_factory=list)
    baselined: List[Finding] = dataclasses.field(default_factory=list)
    stale_baseline: List[dict] = dataclasses.field(default_factory=list)
    baseline_path: Optional[str] = None

    def to_json(self) -> dict:
        by_rule = _Counter(f.rule for f in self.findings)
        return {
            "version": BASELINE_VERSION,
            "tool": "repro.analysis",
            "checkers": self.checkers,
            "files_scanned": len(self.files),
            "findings": [f.to_json() for f in self.findings],
            "new": [f.to_json() for f in self.new],
            "baselined": [f.to_json() for f in self.baselined],
            "suppressed": [f.to_json() for f in self.suppressed],
            "stale_baseline": self.stale_baseline,
            "summary": {
                "total": len(self.findings),
                "new": len(self.new),
                "baselined": len(self.baselined),
                "suppressed": len(self.suppressed),
                "by_rule": dict(sorted(by_rule.items())),
            },
        }


def run_analysis(paths: Sequence[str], *, select: Optional[Sequence[str]] = None,
                 root: Optional[pathlib.Path] = None) -> AnalysisReport:
    """Run every (selected) checker over ``paths``. Baseline comparison is a
    separate step (``diff_against_baseline``) so callers can re-diff one run
    against several baselines (the tests do)."""
    _load_default_checkers()
    names = list(select) if select else sorted(CHECKERS)
    unknown = [n for n in names if n not in CHECKERS]
    if unknown:
        raise KeyError(f"unknown checker(s) {unknown}; "
                       f"known: {sorted(CHECKERS)}")
    files = [sf for sf in collect_files(paths, root=root) if not sf.skip]
    by_rel = {sf.rel: sf for sf in files}

    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for name in names:
        checker = CHECKERS[name]
        produced: List[Finding] = []
        for sf in files:
            if sf.tree is None:
                continue
            produced.extend(checker.check_file(sf))
        produced.extend(checker.check_project(files))
        for f in produced:
            sf = by_rel.get(f.path)
            if sf is not None and sf.is_suppressed(f):
                suppressed.append(f)
            else:
                kept.append(f)
    kept.sort()
    suppressed.sort()
    return AnalysisReport(findings=kept, suppressed=suppressed,
                          files=[sf.rel for sf in files], checkers=names)


# -- baseline ---------------------------------------------------------------

def load_baseline(path) -> List[Finding]:
    data = json.loads(pathlib.Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{data.get('version')!r}")
    return [Finding.from_json(d) for d in data["findings"]]


def save_baseline(path, findings: Sequence[Finding]) -> None:
    data = {
        "version": BASELINE_VERSION,
        "tool": "repro.analysis",
        "note": ("Accepted findings. The pass fails only on findings NOT in "
                 "this file; regenerate with "
                 "`python -m repro.analysis <paths> --update-baseline`."),
        "findings": [f.to_json() for f in sorted(findings)],
    }
    pathlib.Path(path).write_text(json.dumps(data, indent=1) + "\n")


def diff_against_baseline(report: AnalysisReport,
                          baseline: Sequence[Finding]) -> AnalysisReport:
    """Split ``report.findings`` into new vs baselined (multiset semantics:
    two identical findings need two baseline entries). Baseline entries that
    no longer occur are reported as stale — informational, never fatal."""
    budget = _Counter(f.key for f in baseline)
    new, matched = [], []
    for f in report.findings:
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
            matched.append(f)
        else:
            new.append(f)
    report.new = new
    report.baselined = matched
    report.stale_baseline = [
        {"rule": k[0], "path": k[1], "symbol": k[2], "message": k[3],
         "count": c}
        for k, c in sorted(budget.items()) if c > 0]
    return report
