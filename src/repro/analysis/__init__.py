"""repro.analysis: the repo's own static-analysis pass.

``python -m repro.analysis src`` runs five AST-plus-abstract-eval checkers
guarding invariants no generic linter knows about (jit purity, PRNG key
discipline, monotonic-clock durations, Pallas VMEM budgets, obs-registry
hygiene), compares against the committed ``analysis_baseline.json`` and
fails only on NEW findings. See README "Static analysis".
"""
from repro.analysis.framework import (CHECKERS, AnalysisReport, Checker,
                                      Finding, SourceFile,
                                      diff_against_baseline, load_baseline,
                                      run_analysis, save_baseline)

__all__ = ["CHECKERS", "AnalysisReport", "Checker", "Finding", "SourceFile",
           "run_analysis", "load_baseline", "save_baseline",
           "diff_against_baseline"]
