"""jit-purity checker: side effects inside traced functions.

A function handed to ``jax.jit`` / ``jax.pmap`` / ``shard_map`` (or defined
under such a decorator) is traced once and replayed; anything impure in its
body silently becomes a trace-time constant or a once-per-compile effect:

- ``time.time()`` (any clock) → frozen at trace time — the wall-clock
  ``proposals_per_sec`` bug class;
- ``print`` → fires at trace time only (use ``jax.debug.print``);
- mutation of a closure/global container (``stats.append(...)``) → runs once
  per compile, not per call;
- ``global``/``nonlocal`` rebinding → same;
- Python ``if``/``while`` on a traced argument → ``TracerBoolError`` at best,
  silently-specialized control flow at worst (use ``lax.cond``/``lax.select``
  or mark the argument static).

Detection is name-based and deliberately conservative: a def is a jit
context when a jit-ish decorator sits on it or its name is passed as the
first argument to a jit-ish call in the same module (lambdas passed inline
are checked too). Arguments named by ``static_argnames=(...)`` literals are
exempt from the tracer-branch rule, as are ``is None`` / ``isinstance``
tests and attribute/subscript accesses (config objects are static).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from repro.analysis.framework import Checker, Finding, SourceFile, register

RULE = "jit-purity"

# suffix-matched on the unparse of a Call's func: jax.jit, functools.partial
# over jax.jit, pjit, shard_map, pmap all land here
_JIT_SUFFIXES = ("jit", "pmap", "shard_map")
_CLOCK_CALLS = {"time.time", "time.monotonic", "time.perf_counter",
                "time.process_time", "datetime.datetime.now",
                "datetime.now"}
_MUTATORS = {"append", "extend", "insert", "add", "update", "setdefault",
             "pop", "popitem", "remove", "discard", "clear", "write",
             "writelines"}


def _callee(call: ast.Call) -> str:
    try:
        return ast.unparse(call.func)
    except Exception:  # pragma: no cover - unparse is total on parsed trees
        return ""


def _is_jitish(node: ast.expr) -> bool:
    """True for ``jax.jit`` / ``shard_map`` / ``pmap`` expressions and for
    ``functools.partial(jax.jit, ...)``-style wrappers around them."""
    if isinstance(node, ast.Call):
        callee = _callee(node)
        if callee.split(".")[-1] == "partial" and node.args:
            return _is_jitish(node.args[0])
        return any(callee.split(".")[-1].endswith(s) for s in _JIT_SUFFIXES)
    try:
        name = ast.unparse(node)
    except Exception:  # pragma: no cover
        return False
    return any(name.split(".")[-1].endswith(s) for s in _JIT_SUFFIXES)


def _static_argnames(node: ast.expr) -> Set[str]:
    """Literal ``static_argnames=`` strings on a jit-ish call, if any."""
    out: Set[str] = set()
    if not isinstance(node, ast.Call):
        return out
    for kw in node.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    out.add(sub.value)
    if node.args and isinstance(node.args[0], ast.Call):
        out |= _static_argnames(node.args[0])  # partial(jax.jit, ...)
    return out


def _jit_contexts(tree: ast.AST) -> List[Tuple[ast.AST, Set[str]]]:
    """(function_node, static_argnames) for every traced def/lambda."""
    defs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    out: List[Tuple[ast.AST, Set[str]]] = []
    seen = set()

    def add(fn, statics):
        if id(fn) not in seen:
            seen.add(id(fn))
            out.append((fn, statics))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jitish(dec):
                    add(node, _static_argnames(dec))
        if isinstance(node, ast.Call) and _is_jitish(node) and node.args:
            target = node.args[0]
            statics = _static_argnames(node)
            if isinstance(target, ast.Lambda):
                add(target, statics)
            elif isinstance(target, ast.Name) and target.id in defs:
                add(defs[target.id], statics)
    return out


def _params(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    return names


def _local_bindings(fn) -> Set[str]:
    """Names assigned anywhere in the function body (approximate locals)."""
    bound: Set[str] = set(_params(fn))
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                         (ast.Store,)):
                bound.add(node.id)
            elif isinstance(node, (ast.For, ast.comprehension)):
                tgt = node.target
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
            elif isinstance(node, ast.withitem) and node.optional_vars:
                for sub in ast.walk(node.optional_vars):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
    return bound


def _tracer_names_in_test(test: ast.expr) -> Iterable[ast.Name]:
    """Bare Name loads that decide the branch: the test itself, ``not x``,
    BoolOp operands, and Compare sides — but not ``is (not) None`` compares,
    not call arguments, not attribute/subscript bases."""
    stack: List[ast.expr] = [test]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            yield node
        elif isinstance(node, ast.BoolOp):
            stack.extend(node.values)
        elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            stack.append(node.operand)
        elif isinstance(node, ast.Compare):
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                continue
            stack.append(node.left)
            stack.extend(node.comparators)


@register
class JitPurityChecker(Checker):
    name = RULE
    description = ("side effects / host branching inside functions traced "
                   "by jax.jit, pmap or shard_map")
    bug_class = ("trace-time-frozen clocks, once-per-compile effects, "
                 "tracer control flow")

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []

        def emit(line, msg):
            findings.append(Finding(rule=self.name, path=sf.rel, line=line,
                                    message=msg, symbol=sf.symbol_at(line)))

        for fn, statics in _jit_contexts(sf.tree):
            params = set(_params(fn))
            traced = params - statics
            local = _local_bindings(fn)
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        callee = _callee(node)
                        if callee in _CLOCK_CALLS:
                            emit(node.lineno,
                                 f"{callee}() inside a jitted function is a "
                                 "trace-time constant")
                        elif callee == "print":
                            emit(node.lineno,
                                 "print() inside a jitted function fires at "
                                 "trace time only (use jax.debug.print)")
                        elif (isinstance(node.func, ast.Attribute)
                              and node.func.attr in _MUTATORS
                              and isinstance(node.func.value, ast.Name)
                              and node.func.value.id not in local):
                            emit(node.lineno,
                                 f"mutation of closed-over "
                                 f"'{node.func.value.id}."
                                 f"{node.func.attr}()' inside a jitted "
                                 "function runs once per compile, not per "
                                 "call")
                    elif isinstance(node, (ast.Global, ast.Nonlocal)):
                        emit(node.lineno,
                             f"{type(node).__name__.lower()} statement "
                             "inside a jitted function rebinds at trace "
                             "time")
                    elif isinstance(node, (ast.If, ast.While)):
                        for nm in _tracer_names_in_test(node.test):
                            if nm.id in traced:
                                emit(node.lineno,
                                     f"Python branch on traced argument "
                                     f"'{nm.id}' (use lax.cond/lax.select "
                                     "or static_argnames)")
        return findings
