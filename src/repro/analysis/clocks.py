"""monotonic-clock checker: durations must not come from the wall clock.

``time.time()`` is subject to NTP slew and step adjustments; a duration
computed as ``time.time() - t0`` can be negative or wildly wrong, which is
how ``proposals_per_sec`` once went infinite mid-benchmark. Durations belong
on ``time.monotonic()`` / ``time.perf_counter()`` (or ``obs.trace_span``,
which does it for you). Wall time is fine for *timestamps* — this checker
only fires when a wall-clock reading reaches a subtraction:

- ``time.time() - anything`` / ``anything - time.time()`` directly, or
- ``x - y`` where either name was assigned from ``time.time()`` anywhere in
  the same function scope (assignment tracking is per-scope and text-based:
  ``t0 = time.time() ... dt = time.time() - t0``).

``from time import time`` aliases are resolved through the import table.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis.framework import Checker, Finding, SourceFile, register

RULE = "monotonic-clock"

_WALL_SUFFIX = ("time.time", "datetime.now", "datetime.utcnow")


def _wall_callees(tree: ast.AST) -> Set[str]:
    """Expression texts that read the wall clock in this module, resolving
    ``import time as t`` / ``from time import time as now`` aliases."""
    out = {"time.time"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time" and alias.asname:
                    out.add(f"{alias.asname}.time")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time":
                for alias in node.names:
                    if alias.name == "time":
                        out.add(alias.asname or alias.name)
    return out


def _is_wall_call(node: ast.expr, wall: Set[str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    try:
        callee = ast.unparse(node.func)
    except Exception:  # pragma: no cover
        return False
    return callee in wall or callee.endswith(_WALL_SUFFIX)


@register
class MonotonicClockChecker(Checker):
    name = RULE
    description = "time.time() readings used in duration arithmetic"
    bug_class = "negative / skewed durations under NTP clock adjustment"

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        wall = _wall_callees(sf.tree)
        findings: List[Finding] = []

        def emit(line):
            findings.append(Finding(
                rule=self.name, path=sf.rel, line=line,
                message=("wall-clock reading used to compute a duration; "
                         "use time.monotonic()/perf_counter() or "
                         "obs.trace_span"),
                symbol=sf.symbol_at(line)))

        # scopes: module + each function, walked separately so a var named
        # t0 in one function doesn't taint another
        scopes = [sf.tree] + [n for n in ast.walk(sf.tree)
                              if isinstance(n, (ast.FunctionDef,
                                                ast.AsyncFunctionDef))]
        for scope in scopes:
            body = getattr(scope, "body", [])
            tainted: Set[str] = set()
            nodes: List[ast.AST] = []

            def visit(node):
                if node is not scope and isinstance(
                        node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
                    return
                nodes.append(node)
                for child in ast.iter_child_nodes(node):
                    visit(child)

            for stmt in body:
                visit(stmt)
            for node in nodes:
                if isinstance(node, ast.Assign) and \
                        _is_wall_call(node.value, wall):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            tainted.add(tgt.id)
            for node in nodes:
                if isinstance(node, ast.BinOp) and \
                        isinstance(node.op, ast.Sub):
                    for side in (node.left, node.right):
                        if _is_wall_call(side, wall) or (
                                isinstance(side, ast.Name)
                                and side.id in tainted):
                            emit(node.lineno)
                            break
        return findings
