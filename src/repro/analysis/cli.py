"""CLI for the static-analysis pass.

    PYTHONPATH=src python -m repro.analysis src            # gate (CI lane)
    PYTHONPATH=src python -m repro.analysis src --json artifacts/analysis/report.json
    PYTHONPATH=src python -m repro.analysis src --update-baseline
    PYTHONPATH=src python -m repro.analysis --list

Exit codes: 0 clean (every finding baselined or suppressed), 1 new
findings, 2 usage errors. The baseline matches findings by
(rule, path, symbol, message) so line churn never trips the gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Optional, Sequence

from repro.analysis import framework as fw


def _human_report(report: fw.AnalysisReport, baseline_used: bool) -> str:
    lines = []
    show = report.new if baseline_used else report.findings
    for f in show:
        sym = f" [{f.symbol}]" if f.symbol else ""
        lines.append(f"{f.path}:{f.line}: {f.rule}: {f.message}{sym}")
    s = report.to_json()["summary"]
    tail = (f"{len(report.files)} files, {len(report.checkers)} checkers: "
            f"{s['total']} findings "
            f"({s['baselined']} baselined, {s['new']} new, "
            f"{s['suppressed']} suppressed)")
    if report.stale_baseline:
        tail += (f"; {len(report.stale_baseline)} stale baseline entr"
                 f"{'y' if len(report.stale_baseline) == 1 else 'ies'} "
                 "(fixed or moved — consider --update-baseline)")
    lines.append(tail)
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis for the jax/pallas stack")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src)")
    ap.add_argument("--baseline", default=fw.DEFAULT_BASELINE,
                    help="baseline JSON ('' disables; default "
                         f"{fw.DEFAULT_BASELINE}, ignored if absent unless "
                         "--update-baseline)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write every current finding to --baseline and "
                         "exit 0")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write the full machine-readable report here")
    ap.add_argument("--select", default="",
                    help="comma-separated checker names (default: all)")
    ap.add_argument("--root", default=".",
                    help="paths in the report are relative to this "
                         "(default: cwd; must match the baseline's root)")
    ap.add_argument("--list", action="store_true",
                    help="list registered checkers and exit")
    args = ap.parse_args(argv)

    if args.list:
        fw._load_default_checkers()
        for name in sorted(fw.CHECKERS):
            c = fw.CHECKERS[name]
            print(f"{name}: {c.description}")
            print(f"    guards against: {c.bug_class}")
        return 0

    paths = args.paths or ["src"]
    select = [s.strip() for s in args.select.split(",") if s.strip()] or None
    try:
        report = fw.run_analysis(paths, select=select,
                                 root=pathlib.Path(args.root))
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    if args.update_baseline:
        if not args.baseline:
            print("error: --update-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        fw.save_baseline(args.baseline, report.findings)
        print(f"wrote {len(report.findings)} findings to {args.baseline}")
        return 0

    baseline_used = False
    bp = pathlib.Path(args.baseline) if args.baseline else None
    if bp is not None and bp.exists():
        report = fw.diff_against_baseline(report, fw.load_baseline(bp))
        report.baseline_path = str(bp)
        baseline_used = True
    else:
        report.new = list(report.findings)

    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_json(), indent=1) + "\n")

    print(_human_report(report, baseline_used))
    return 1 if report.new else 0
