"""PRNG-discipline checker: a JAX key is consumed exactly once.

``jax.random`` is counter-based: passing the same key to two samplers (or to
a sampler and a later ``split``) yields CORRELATED streams, and carrying a
key across loop iterations without re-splitting replays the same stream
every iteration. Both are silent — outputs look random — which is exactly
why the PR 4 sample-stream fork shipped. This checker tracks key-valued
expressions per function body:

- a binding is any assignment from ``PRNGKey`` / ``key`` / ``split`` /
  ``fold_in`` (tuple targets of ``split`` bind every element), plus
  parameters with key-ish names (``key``, ``rng``, ``*_key``, ``*_rng``);
- a consumption is that expression appearing as the first argument of any
  ``jax.random.*`` call (samplers, ``split`` and ``fold_in`` all consume).

Flagged:

- **reuse**: the same key expression consumed twice with no rebinding in
  between (``k, sub = split(k)`` on one line rebinds, so the engine's
  ``isl.key, sub = jax.random.split(isl.key)`` idiom passes);
- **loop-carry**: a key bound before a ``for``/``while`` consumed inside it
  without an in-loop rebinding. Indexing a pre-split key array by the loop
  variable (``ks[i]``) is the correct idiom and is exempt.

Tracking is by source text (``ast.unparse``) of the key expression, so
``self.key`` / ``keys[i]`` / plain names all participate without real
dataflow analysis — cheap, and precise enough for this codebase's idioms.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.framework import Checker, Finding, SourceFile, register

RULE = "prng-discipline"

_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in"}
_KEY_PARAM_RE = re.compile(r"(^|_)(key|rng|prng)s?$")


def _callee(call: ast.Call) -> str:
    try:
        return ast.unparse(call.func)
    except Exception:  # pragma: no cover
        return ""


def _is_random_call(call: ast.Call) -> bool:
    """Any ``jax.random.<fn>`` / ``random.<fn>`` / bare ``<fn>`` imported
    from jax.random — recognized by the trailing attribute living in the
    jax.random namespace, with the receiver not obviously something else."""
    callee = _callee(call)
    parts = callee.split(".")
    if len(parts) >= 2 and parts[-2] in ("random", "jrandom", "jr"):
        return True
    return False


def _key_exprs_consumed(call: ast.Call) -> List[ast.expr]:
    """The key operand(s) of a jax.random call: by convention the first
    positional argument, or a ``key=`` keyword."""
    if call.args:
        return [call.args[0]]
    return [kw.value for kw in call.keywords if kw.arg == "key"]


class _Event:
    __slots__ = ("pos", "kind", "expr", "node")

    def __init__(self, pos: Tuple[int, int], kind: str, expr: str, node):
        self.pos = pos        # (line, col) in statement order
        self.kind = kind      # "bind" | "use"
        self.expr = expr
        self.node = node


def _function_bodies(tree: ast.AST):
    """Yield (qualname-ish owner node, body stmt list) for the module and
    every def; nested defs get their own scope."""
    yield tree, list(getattr(tree, "body", []))
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.body


def _scan_scope(fn, body: List[ast.stmt]):
    """Collect bind/use events for key expressions in ONE scope (nested defs
    are skipped — they are their own scope)."""
    events: List[_Event] = []
    keyish: Set[str] = set()

    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = fn.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if _KEY_PARAM_RE.search(p.arg):
                keyish.add(p.arg)
                events.append(_Event((fn.lineno, 0), "bind", p.arg, fn))

    def visit(node):
        """Recursive walk that does NOT enter nested function scopes."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, ast.Call) and _is_random_call(node):
            for key_arg in _key_exprs_consumed(node):
                try:
                    expr = ast.unparse(key_arg)
                except Exception:  # pragma: no cover
                    continue
                events.append(_Event(
                    (key_arg.lineno, key_arg.col_offset + 10_000),
                    "use", expr, node))
        if isinstance(node, ast.Assign):
            val = node.value
            if isinstance(val, ast.Call) and _is_random_call(val) and \
                    _callee(val).split(".")[-1] in _KEY_MAKERS:
                for tgt in node.targets:
                    targets = (list(tgt.elts)
                               if isinstance(tgt, (ast.Tuple, ast.List))
                               else [tgt])
                    for t in targets:
                        try:
                            expr = ast.unparse(t)
                        except Exception:  # pragma: no cover
                            continue
                        keyish.add(expr)
                        # binds take effect AFTER the value's uses on the
                        # same line: sort col after the use marker
                        events.append(_Event(
                            (node.lineno, t.col_offset + 20_000),
                            "bind", expr, node))
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in body:
        for child in ([stmt] if not isinstance(
                stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) else []):
            visit(child)
    return events, keyish


@register
class PrngDisciplineChecker(Checker):
    name = RULE
    description = ("jax.random keys consumed more than once or carried "
                   "across loop iterations without splitting")
    bug_class = "correlated / forked sample streams (silent)"

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []

        def emit(line, msg):
            findings.append(Finding(rule=self.name, path=sf.rel, line=line,
                                    message=msg, symbol=sf.symbol_at(line)))

        for fn, body in _function_bodies(sf.tree):
            events, keyish = _scan_scope(fn, body)
            events.sort(key=lambda e: e.pos)

            def tracked(expr: str) -> bool:
                # "ks[0]" rides on its base "ks" (a pre-split key array)
                return expr in keyish or expr.split("[")[0] in keyish

            # --- reuse: two uses of one expr with no bind in between -----
            last_use: Dict[str, Tuple[int, int]] = {}
            for ev in events:
                if not tracked(ev.expr):
                    continue
                if ev.kind == "bind":
                    last_use.pop(ev.expr, None)
                    for stale in [k for k in last_use
                                  if k.split("[")[0] == ev.expr]:
                        last_use.pop(stale)
                elif ev.kind == "use":
                    if ev.expr in last_use:
                        emit(ev.node.lineno,
                             f"key '{ev.expr}' consumed again without "
                             "re-splitting")
                    else:
                        last_use[ev.expr] = ev.pos

            # --- loop-carry: outer key consumed in a loop, no inner bind -
            loops = [n for s in body for n in ast.walk(s)
                     if isinstance(n, (ast.For, ast.While))]
            for loop in loops:
                span = (loop.lineno, getattr(loop, "end_lineno", loop.lineno))
                loop_vars: Set[str] = set()
                if isinstance(loop, ast.For):
                    for sub in ast.walk(loop.target):
                        if isinstance(sub, ast.Name):
                            loop_vars.add(sub.id)
                inner = [e for e in events if span[0] < e.pos[0] <= span[1]]
                inner_binds = {e.expr for e in inner if e.kind == "bind"}
                outer_binds = {e.expr for e in events
                               if e.kind == "bind" and e.pos[0] < span[0]}

                def bound_in(expr: str, binds: Set[str]) -> bool:
                    return expr in binds or expr.split("[")[0] in binds

                reported: Set[str] = set()
                for ev in inner:
                    if (ev.kind != "use" or bound_in(ev.expr, inner_binds)
                            or not bound_in(ev.expr, outer_binds)
                            or ev.expr in reported):
                        continue
                    # ks[i] with i a loop variable = pre-split array: fine
                    if loop_vars and any(
                            v in re.findall(r"[A-Za-z_][A-Za-z0-9_]*",
                                            ev.expr)[1:]
                            for v in loop_vars):
                        continue
                    # fold_in(key, i) with i a loop variable derives a
                    # fresh per-iteration key — the recommended fix
                    if (isinstance(ev.node, ast.Call)
                            and _callee(ev.node).split(".")[-1] == "fold_in"
                            and loop_vars
                            and any(isinstance(a, ast.Name)
                                    and a.id in loop_vars
                                    for a in ev.node.args[1:])):
                        continue
                    reported.add(ev.expr)
                    emit(ev.node.lineno,
                         f"key '{ev.expr}' crosses loop iterations unsplit "
                         "(bound before the loop; split or fold_in per "
                         "iteration)")
        return findings
