"""metrics-hygiene checker: one name, one kind, one label schema.

The obs registry's multi-host aggregation merges snapshots *exactly* —
which only holds if every host agrees on what a metric IS. Two failure
modes break the merge silently:

- the same name registered as two different kinds (a counter on one code
  path, a histogram on another): merge semantics diverge per host;
- the same metric written with different label-key sets (``.inc()`` here,
  ``.inc(reason=...)`` there): series fan out inconsistently and
  Prometheus-text export emits mixed schemas under one HELP block.

This is a project-wide checker: registrations are collected across every
scanned file. Registration sites are calls to ``counter`` / ``gauge`` /
``histogram`` (method or bare import) with a literal string name. Usage
sites (``.inc`` / ``.observe`` / ``.set``) are tied back to a metric name
by resolving the receiver expression through, per file:

- direct chaining: ``obs.counter("x_total", "...").inc()``;
- handle assignment: ``self._c = reg.counter("x_total", ...)``;
- dict-literal registries: ``self.obs = {"cow": reg.counter(...), ...}``
  and functions that *return* such a dict literal
  (``metrics = _search_metrics(reg)`` → ``metrics["cow"]``).

Receivers that don't resolve (function parameters, non-metric objects with
a ``.set()``) are ignored — the checker never guesses.
"""
from __future__ import annotations

import ast
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.framework import Checker, Finding, SourceFile, register

RULE = "metrics-hygiene"

_KINDS = {"counter", "gauge", "histogram"}
_WRITES = {"inc", "observe", "set"}


def _callee_tail(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _reg_call(node: ast.expr) -> Optional[Tuple[str, str]]:
    """(metric_name, kind) when ``node`` is a registration with a literal
    string name; None otherwise."""
    if not isinstance(node, ast.Call):
        return None
    kind = _callee_tail(node)
    if kind not in _KINDS:
        return None
    args = node.args
    if args and isinstance(args[0], ast.Constant) and \
            isinstance(args[0].value, str):
        return args[0].value, kind
    for kw in node.keywords:
        if kw.arg == "name" and isinstance(kw.value, ast.Constant) and \
                isinstance(kw.value.value, str):
            return kw.value.value, kind
    return None


def _dict_literal_handles(d: ast.Dict) -> Dict[str, str]:
    """{literal_key: metric_name} for registration-valued dict entries."""
    out: Dict[str, str] = {}
    for k, v in zip(d.keys, d.values):
        reg = _reg_call(v)
        if reg and isinstance(k, ast.Constant) and isinstance(k.value, str):
            out[k.value] = reg[0]
    return out


@register
class MetricsHygieneChecker(Checker):
    name = RULE
    description = ("metric names registered under one kind and written "
                   "with one label-key schema")
    bug_class = "divergent multi-host merges / mixed Prometheus schemas"

    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        # metric -> list of (kind, path, line, symbol)
        regs: Dict[str, List[Tuple[str, str, int, str]]] = defaultdict(list)
        # metric -> list of (frozen label keys, path, line, symbol)
        uses: Dict[str, List[Tuple[Tuple[str, ...], str, int, str]]] = \
            defaultdict(list)

        for sf in files:
            if sf.tree is None:
                continue
            handles: Dict[str, str] = {}     # receiver text -> metric name
            dict_fns: Dict[str, Dict[str, str]] = {}

            for node in ast.walk(sf.tree):
                # function returning a dict literal of registrations
                if isinstance(node, ast.FunctionDef):
                    for stmt in node.body:
                        if isinstance(stmt, ast.Return) and \
                                isinstance(stmt.value, ast.Dict):
                            entries = _dict_literal_handles(stmt.value)
                            if entries:
                                dict_fns[node.name] = entries
                if not isinstance(node, ast.Assign):
                    continue
                val, targets = node.value, node.targets
                reg = _reg_call(val)
                if reg:
                    for t in targets:
                        handles[ast.unparse(t)] = reg[0]
                elif isinstance(val, ast.Dict):
                    entries = _dict_literal_handles(val)
                    for t in targets:
                        base = ast.unparse(t)
                        for key, metric in entries.items():
                            handles[f"{base}[{key!r}]"] = metric

            # second pass: resolve `m = _search_metrics(...)` through the
            # dict-returning functions found above
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call):
                    fname = _callee_tail(node.value)
                    if fname in dict_fns:
                        for t in node.targets:
                            base = ast.unparse(t)
                            for key, metric in dict_fns[fname].items():
                                handles[f"{base}[{key!r}]"] = metric

            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                reg = _reg_call(node)
                if reg:
                    regs[reg[0]].append((reg[1], sf.rel, node.lineno,
                                         sf.symbol_at(node.lineno)))
                    continue
                f = node.func
                if not (isinstance(f, ast.Attribute) and f.attr in _WRITES):
                    continue
                recv = f.value
                metric = None
                inner = _reg_call(recv)
                if inner:                      # chained .inc() on the call
                    metric = inner[0]
                else:
                    metric = handles.get(ast.unparse(recv))
                if metric is None:
                    continue
                labels = tuple(sorted(kw.arg for kw in node.keywords
                                      if kw.arg))
                uses[metric].append((labels, sf.rel, node.lineno,
                                     sf.symbol_at(node.lineno)))

        findings: List[Finding] = []
        for metric, sites in sorted(regs.items()):
            kinds = sorted({k for k, *_ in sites})
            if len(kinds) > 1:
                for kind, path, line, symbol in sites:
                    findings.append(Finding(
                        rule=self.name, path=path, line=line, symbol=symbol,
                        message=(f"metric '{metric}' registered as "
                                 f"{' and '.join(kinds)}; a name must have "
                                 "exactly one kind")))
        for metric, sites in sorted(uses.items()):
            schemas = {labels for labels, *_ in sites}
            if len(schemas) > 1:
                canonical = sorted(schemas, key=lambda s: (-sum(
                    1 for labels, *_ in sites if labels == s), s))[0]
                for labels, path, line, symbol in sites:
                    if labels == canonical:
                        continue
                    findings.append(Finding(
                        rule=self.name, path=path, line=line, symbol=symbol,
                        message=(f"metric '{metric}' written with label "
                                 f"keys {list(labels)} but predominantly "
                                 f"with {list(canonical)}; label schemas "
                                 "must agree")))
        return findings
