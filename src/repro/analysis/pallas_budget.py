"""pallas-budget checker: VMEM strips must fit, at lint time.

``transform_quant`` guards its Pallas path at runtime: shapes that blow the
``_TQ_STRIP_BYTES`` VMEM budget (or break grid/block divisibility) silently
fall back to the jnp reference — correct, but the fused kernel's whole
point is performance, and a config that *always* falls back should be a
lint finding, not a surprise in a profile. This checker replays the
wrapper's planner (``repro.kernels.ops.tq_plan`` — the same code the
runtime guard calls) over every architecture in the config zoo:

- for each config with an FFN, the search adapters quantize
  ``up``-family weights of shape (d_model, d_ff) in ``mode="up"`` and the
  ``down`` projection (d_ff, d_model) in ``mode="down"`` under the
  canonical search ``QuantConfig(bits=2, group_size=32)``;
- each weight is abstract-evaluated through the real wrapper with
  ``jax.eval_shape`` (catching group-divisibility and shape-contract
  breaks without touching a device), then ``tq_plan`` delivers the
  strip-bytes / divisibility verdict.

Findings anchor at the ``pl.pallas_call`` site inside
``transform_quant_pallas`` — the kernel the config can't use. Expected
fallbacks (large-d_ff archs awaiting the two-stage ROADMAP variant) live
in the committed baseline.

Fixture/self-test hook: any scanned file may declare a literal
``TQ_SHAPE_PROBES = [(K, N, group, "mode"), ...]``; each failing probe is
a finding at the declaration — this is how the checker's own test corpus
exercises the budget logic without importing the zoo.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.framework import Checker, Finding, SourceFile, register

RULE = "pallas-budget"

# the canonical search quantization the zoo is validated under
_BITS, _GROUP_SIZE = 2, 32


def zoo_units() -> List[dict]:
    """One row per (arch, projection): the transform_quant call shapes the
    search adapters produce, with the planner's verdict and — when jax is
    importable — the ``jax.eval_shape`` result through the real wrapper."""
    import functools

    from repro.configs import get_config, list_archs
    from repro.core.quant import QuantConfig
    from repro.kernels import ops

    qcfg = QuantConfig(bits=_BITS, group_size=_GROUP_SIZE)
    rows: List[dict] = []
    for arch in list_archs() + ["opt-1.3b"]:
        cfg = get_config(arch)
        d, f = cfg.d_model, cfg.d_ff
        if not f:  # pure-SSM archs have no FFN unit to transform
            rows.append({"arch": arch, "proj": None, "ok": True,
                         "reason": "no FFN"})
            continue
        for proj, K, N, mode in (("up", d, f, "up"), ("down", f, d, "down")):
            row = {"arch": arch, "proj": proj, "K": K, "N": N, "mode": mode,
                   "group": None, "ok": False, "strip_bytes": 0,
                   "reason": "", "eval_shape": None}
            try:
                group = qcfg.resolve_group(K)
            except ValueError as e:
                row["reason"] = f"group resolution failed: {e}"
                rows.append(row)
                continue
            row["group"] = group
            plan = ops.tq_plan(K, N, group=group, mode=mode)
            row.update(ok=plan.ok, strip_bytes=plan.strip_bytes,
                       reason=plan.reason)
            try:
                import jax
                import jax.numpy as jnp
                w = jax.ShapeDtypeStruct((K, N), jnp.float32)
                pi = jax.ShapeDtypeStruct((plan.f,), jnp.int32)
                s = jax.ShapeDtypeStruct((plan.f,), jnp.float32)
                phi = jax.ShapeDtypeStruct((plan.f // 2,), jnp.float32)
                out = jax.eval_shape(
                    functools.partial(ops.transform_quant, bits=_BITS,
                                      group=group, mode=mode,
                                      use_pallas=False), w, pi, s, phi)
                row["eval_shape"] = tuple(tuple(o.shape) for o in out)
                if tuple(out[0].shape) != (K, N):
                    row["ok"] = False
                    row["reason"] = (f"eval_shape contract break: fq shape "
                                     f"{tuple(out[0].shape)} != {(K, N)}")
            except ImportError:
                pass  # planner verdict stands; abstract eval needs jax
            rows.append(row)
    return rows


def _find_anchor(files: Sequence[SourceFile]) -> Optional[Tuple[SourceFile,
                                                                int]]:
    """The ``pl.pallas_call`` inside ``transform_quant_pallas``."""
    for sf in files:
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.FunctionDef) and \
                    node.name == "transform_quant_pallas":
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        try:
                            callee = ast.unparse(sub.func)
                        except Exception:  # pragma: no cover
                            continue
                        if callee.split(".")[-1] == "pallas_call":
                            return sf, sub.lineno
    return None


def _literal_probes(sf: SourceFile) -> List[Tuple[int, Tuple]]:
    """(line, (K, N, group, mode)) per entry of a literal TQ_SHAPE_PROBES."""
    out: List[Tuple[int, Tuple]] = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "TQ_SHAPE_PROBES"
                for t in node.targets)):
            continue
        try:
            probes = ast.literal_eval(node.value)
        except (ValueError, SyntaxError):
            continue
        for entry in probes:
            out.append((node.lineno, tuple(entry)))
    return out


@register
class PallasBudgetChecker(Checker):
    name = RULE
    description = ("transform_quant shapes across the config zoo fit the "
                   "_TQ_STRIP_BYTES VMEM budget and tiling constraints")
    bug_class = "silent jnp-reference fallback on the fused hot path"

    def check_file(self, sf: SourceFile) -> Iterable[Finding]:
        from repro.kernels import ops

        findings: List[Finding] = []
        for line, entry in _literal_probes(sf):
            try:
                K, N, group, mode = entry
                plan = ops.tq_plan(int(K), int(N), group=int(group),
                                   mode=str(mode))
            except (TypeError, ValueError) as e:
                findings.append(Finding(
                    rule=self.name, path=sf.rel, line=line,
                    symbol=sf.symbol_at(line),
                    message=f"malformed TQ_SHAPE_PROBES entry {entry!r}: "
                            f"{e}"))
                continue
            if not plan.ok:
                findings.append(Finding(
                    rule=self.name, path=sf.rel, line=line,
                    symbol=sf.symbol_at(line),
                    message=(f"probe (K={K}, N={N}, group={group}, "
                             f"mode={mode}) cannot use the Pallas kernel: "
                             f"{plan.reason}")))
        return findings

    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        anchor = _find_anchor(files)
        if anchor is None:
            return []  # kernel not in the scan set (e.g. fixture runs)
        sf, line = anchor
        symbol = sf.symbol_at(line)
        findings: List[Finding] = []
        for row in zoo_units():
            if row["ok"] or row["proj"] is None:
                continue
            findings.append(Finding(
                rule=self.name, path=sf.rel, line=line, symbol=symbol,
                message=(f"config {row['arch']} ffn_{row['proj']} "
                         f"(K={row['K']}, N={row['N']}, "
                         f"group={row['group']}, mode={row['mode']}) "
                         f"falls back to the jnp reference: "
                         f"{row['reason']}")))
        return findings
