"""Span tracing: wall-time + monotonic-duration events to a JSONL sink.

``trace_span(name, **attrs)`` is a context manager that emits a begin event
(``{"ph": "B", "name", "ts", ...attrs}``) and an end event
(``{"ph": "E", "name", "ts", "dur_s"}``) to the configured sink, measuring
the duration on the MONOTONIC clock. Event ``ts`` values are wall-clock
*valued* but monotonically *derived*: the module captures one
(wall, monotonic) epoch anchor pair at import, every subsequent ``ts`` is
``anchor_wall + (monotonic() - anchor_mono)``, and each sink gets the
anchor written once as a ``{"ph": "M", "name": "clock_anchor"}`` metadata
event. Hosts still line up (via the anchor) but an NTP step mid-run can no
longer reorder or overlap spans within a trace — ``E.ts - B.ts`` is exactly
``dur_s`` by construction. With no sink configured a span still times
itself — callers use
``span.dur`` / ``span.elapsed()`` for metrics — at the cost of two
``perf_counter``-class calls, so instrumenting a hot loop is safe.

Optional integrations:

  hist=       an ``obs.Histogram``; the span observes its duration on exit,
              so "span timing" and "latency histogram" are one call site.
  xprof=True  wraps the body in ``jax.profiler.TraceAnnotation`` (or
              ``StepTraceAnnotation`` when a ``step=`` attr is present), so
              the same spans line up against XLA device activity in a
              ``jax.profiler.trace`` capture. Off by default
              (``enable_xprof()`` flips the process default).

``emit(name, **fields)`` writes a structured instant event (``"ph": "i"``)
and prints a compact ``[name] k=v ...`` line — the replacement for ad-hoc
progress ``print``s in the search engine.
"""
from __future__ import annotations

import json
import threading
import time
from typing import IO, Optional, Union

__all__ = ["trace_span", "emit", "set_trace_sink", "get_trace_sink",
           "trace_to", "enable_xprof"]

_lock = threading.Lock()
_sink: Optional[IO] = None
_sink_owned = False        # opened by us (close on replace) vs caller-owned
_xprof_default = False

# one epoch anchor per process: all event timestamps derive from the
# monotonic clock relative to this pair, so wall-clock adjustments cannot
# shuffle spans within a trace
_EPOCH_WALL = time.time()
_EPOCH_MONO = time.monotonic()


def _now_ts() -> float:
    """Wall-valued, monotonically-derived timestamp."""
    return _EPOCH_WALL + (time.monotonic() - _EPOCH_MONO)


def _write_anchor() -> None:
    """Stamp the sink with the epoch anchor (once per installed sink)."""
    _write({"ph": "M", "name": "clock_anchor",
            "wall": _EPOCH_WALL, "mono": _EPOCH_MONO})


def enable_xprof(on: bool = True) -> None:
    """Process default for the ``jax.profiler`` annotation passthrough."""
    global _xprof_default
    _xprof_default = bool(on)


def _open(sink: Union[str, IO, None]):
    """Resolve a sink spec to (file_or_None, owned_by_us)."""
    if isinstance(sink, str):
        import pathlib
        p = pathlib.Path(sink)
        p.parent.mkdir(parents=True, exist_ok=True)
        return p.open("a"), True
    return sink, False


def set_trace_sink(sink: Union[str, IO, None]) -> None:
    """Point span/event output at a JSONL file. A string opens (appends) the
    path; a file-like object is used as-is; ``None`` disables tracing."""
    global _sink, _sink_owned
    new, owned = _open(sink)
    with _lock:
        if _sink is not None and _sink_owned:
            try:
                _sink.close()
            except OSError:
                pass
        _sink, _sink_owned = new, owned
    if new is not None:
        _write_anchor()


def get_trace_sink() -> Optional[IO]:
    return _sink


class trace_to:
    """Scoped sink: ``with trace_to(path): ...`` restores the previous sink
    on exit (tests, nested drivers). The previous sink is left open."""

    def __init__(self, sink: Union[str, IO, None]):
        self._spec = sink

    def __enter__(self):
        global _sink, _sink_owned
        new, owned = _open(self._spec)
        with _lock:
            self._prev, self._prev_owned = _sink, _sink_owned
            _sink, _sink_owned = new, owned
        if new is not None:
            _write_anchor()
        return self

    def __exit__(self, *exc):
        global _sink, _sink_owned
        with _lock:
            if _sink is not None and _sink_owned:
                try:
                    _sink.close()
                except OSError:
                    pass
            _sink, _sink_owned = self._prev, self._prev_owned
        return False


def _write(event: dict) -> None:
    sink = _sink
    if sink is None:
        return
    line = json.dumps(event) + "\n"
    with _lock:
        sink.write(line)
        sink.flush()


class trace_span:
    """Context manager; after exit ``.dur`` holds the monotonic duration in
    seconds. ``elapsed()`` reads the running duration while still open."""

    __slots__ = ("name", "attrs", "hist", "hist_labels", "xprof", "t_wall",
                 "_t0", "dur", "_annotation")

    def __init__(self, name: str, hist=None, hist_labels: Optional[dict] = None,
                 xprof: Optional[bool] = None, **attrs):
        self.name = name
        self.attrs = attrs
        self.hist = hist
        self.hist_labels = hist_labels or {}
        self.xprof = _xprof_default if xprof is None else xprof
        self.dur: Optional[float] = None
        self._annotation = None

    def elapsed(self) -> float:
        return time.monotonic() - self._t0

    def __enter__(self):
        if self.xprof:
            self._annotation = _make_annotation(self.name, self.attrs)
            if self._annotation is not None:
                self._annotation.__enter__()
        self.t_wall = _now_ts()
        self._t0 = time.monotonic()
        if _sink is not None:
            _write({"ph": "B", "name": self.name, "ts": self.t_wall,
                    **({"attrs": self.attrs} if self.attrs else {})})
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur = time.monotonic() - self._t0
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
            self._annotation = None
        if self.hist is not None:
            self.hist.observe(self.dur, **self.hist_labels)
        if _sink is not None:
            # derived from the begin stamp so E.ts - B.ts == dur_s exactly
            _write({"ph": "E", "name": self.name,
                    "ts": self.t_wall + self.dur,
                    "dur_s": self.dur,
                    **({"error": repr(exc)} if exc is not None else {})})
        return False


def _make_annotation(name: str, attrs: dict):
    """``StepTraceAnnotation`` when a step attribute rides along (XLA step
    markers), plain ``TraceAnnotation`` otherwise; None when the profiler
    API is unavailable (ancient jax)."""
    try:
        from jax import profiler
        if "step" in attrs:
            return profiler.StepTraceAnnotation(name,
                                                step_num=int(attrs["step"]))
        return profiler.TraceAnnotation(name)
    except Exception:  # noqa: BLE001 — tracing must never take the run down
        return None


def emit(name: str, _print: bool = True, **fields) -> str:
    """Structured instant event + compact human line. Returns the line."""
    if _sink is not None:
        _write({"ph": "i", "name": name, "ts": _now_ts(), **fields})
    line = f"[{name}] " + " ".join(f"{k}={v}" for k, v in fields.items())
    if _print:
        print(line, flush=True)
    return line
