"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Zero-dependency (stdlib only) by design — the registry must be importable
before jax is configured (``dist.runtime.initialize`` has to run before the
first computation) and must not drag a metrics client into the container.

Three instrument kinds, chosen so MERGING IS EXACT:

  Counter     monotonically increasing float; merge = sum.
  Gauge       last-set value; merge keeps (min, max, sum, n) so a fleet
              report can answer "worst host" and "fleet total" without
              pretending one number speaks for N processes.
  Histogram   fixed bucket edges declared at creation; observations land in
              the first bucket with ``value <= edge`` (Prometheus ``le``
              semantics) plus an implicit +Inf bucket. Because the edges are
              fixed, merging is a bucket-wise integer add — associative and
              commutative, so any aggregation order over any host subset
              yields the same fleet histogram (pinned by
              ``tests/test_obs.py``).

Series are keyed by free-form labels (``counter.inc(1, reason="cow")``); a
label-less call is the single unlabeled series. All mutation is lock-guarded
so background writers (the async checkpoint thread) can report safely.

``Registry.snapshot()`` produces the canonical JSON-able form that
``merge_snapshots`` consumes and ``obs.aggregate.dist_snapshot`` exchanges
across hosts; ``render_prometheus()`` emits the text exposition format.
``reset()`` zeroes every series IN PLACE, so instrument handles held by
instrumented code stay valid across runs (the batcher-reuse contract).
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "merge_snapshots",
           "hist_quantile", "LATENCY_BUCKETS_S", "get_registry",
           "counter", "gauge", "histogram"]

# geometric ladder from 100us to 2 minutes: wide enough for a CPU-container
# TTFT and a real-accelerator decode step to land in informative buckets
LATENCY_BUCKETS_S = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0,
)


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    kind = "abstract"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def _reset(self) -> None:
        with self._lock:
            self._series.clear()

    def _sorted_items(self):
        return sorted(self._series.items(), key=lambda kv: kv[0])


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every label series (the "all reasons" roll-up)."""
        with self._lock:
            return float(sum(self._series.values()))

    def _snapshot_series(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(k), "value": float(v)}
                    for k, v in self._sorted_items()]


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = float(value)

    def value(self, **labels) -> Optional[float]:
        v = self._series.get(_label_key(labels))
        return None if v is None else float(v)

    def _snapshot_series(self) -> List[dict]:
        # canonical (min, max, sum, n) form: a single-host snapshot is the
        # degenerate n=1 aggregate, so local and merged snapshots share one
        # schema and merging is closed
        with self._lock:
            return [{"labels": dict(k), "min": float(v), "max": float(v),
                     "sum": float(v), "n": 1}
                    for k, v in self._sorted_items()]


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets=LATENCY_BUCKETS_S):
        super().__init__(name, help)
        edges = tuple(float(e) for e in buckets)
        if list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name}: bucket edges must be "
                             f"strictly increasing, got {edges}")
        self.edges = edges

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        i = bisect.bisect_left(self.edges, value)  # le: value == edge counts
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = {"counts": [0] * (len(self.edges) + 1),
                     "sum": 0.0, "count": 0}
                self._series[key] = s
            s["counts"][i] += 1
            s["sum"] += float(value)
            s["count"] += 1

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return 0 if s is None else int(s["count"])

    def quantile(self, q: float, **labels) -> float:
        s = self._series.get(_label_key(labels))
        if s is None:
            return 0.0
        return hist_quantile(s["counts"], self.edges, q)

    def _snapshot_series(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(k), "counts": list(v["counts"]),
                     "sum": float(v["sum"]), "count": int(v["count"])}
                    for k, v in self._sorted_items()]


def hist_quantile(counts, edges, q: float) -> float:
    """q-quantile from per-bucket counts, linearly interpolated inside the
    bucket the rank falls in — exact to within one bucket width. The open
    +Inf bucket clamps to the largest finite edge."""
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = min(max(q, 0.0), 1.0) * total
    cum = 0
    for i, c in enumerate(counts):
        prev, cum = cum, cum + c
        if cum >= rank and c > 0:
            if i >= len(edges):           # +Inf bucket: no finite upper edge
                return float(edges[-1])
            lo = edges[i - 1] if i > 0 else 0.0
            frac = (rank - prev) / c
            return float(lo + (edges[i] - lo) * frac)
    return float(edges[-1])


class Registry:
    """Get-or-create instrument store. Re-requesting a name returns the SAME
    instrument (kind and — for histograms — bucket edges must match), so any
    module can say ``obs.counter("x_total")`` without coordination."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        if isinstance(m, Histogram) and "buckets" in kw and \
                tuple(float(e) for e in kw["buckets"]) != m.edges:
            raise ValueError(f"histogram {name!r} already registered with "
                             f"different bucket edges")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_BUCKETS_S) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def reset(self) -> None:
        """Zero every series; instruments (and handles to them) survive."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m._reset()

    def snapshot(self) -> dict:
        """Canonical JSON-able snapshot: name -> {kind, help, [edges,]
        series}. Deterministically ordered (names and label sets sorted) so
        equal registries serialize to equal JSON."""
        with self._lock:
            metrics = sorted(self._metrics.items())
        out = {}
        for name, m in metrics:
            entry = {"kind": m.kind, "help": m.help,
                     "series": m._snapshot_series()}
            if isinstance(m, Histogram):
                entry["edges"] = list(m.edges)
            out[name] = entry
        return out

    def render_prometheus(self) -> str:
        """Prometheus/OpenMetrics text exposition of the LIVE registry."""
        lines: List[str] = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if m.kind == "histogram":
                for s in m._snapshot_series():
                    lbl = _label_key(s["labels"])
                    cum = 0
                    for edge, c in zip(m.edges, s["counts"]):
                        cum += c
                        lines.append(f"{name}_bucket"
                                     f"{_fmt_labels(lbl + (('le', repr(edge)),))}"
                                     f" {cum}")
                    cum += s["counts"][-1]
                    lines.append(f"{name}_bucket"
                                 f"{_fmt_labels(lbl + (('le', '+Inf'),))} {cum}")
                    lines.append(f"{name}_sum{_fmt_labels(lbl)} {s['sum']}")
                    lines.append(f"{name}_count{_fmt_labels(lbl)} {s['count']}")
            else:
                with m._lock:
                    items = m._sorted_items()
                for key, v in items:
                    lines.append(f"{name}{_fmt_labels(key)} {v}")
        return "\n".join(lines) + "\n"


def _merge_series(kind: str, a: List[dict], b: List[dict]) -> List[dict]:
    by_key: Dict[tuple, dict] = {}
    for src in (a, b):
        for s in src:
            key = _label_key(s["labels"])
            cur = by_key.get(key)
            if cur is None:
                s = dict(s)
                if kind == "gauge":     # normalize away any stray value field
                    s = {"labels": s["labels"], "min": s["min"],
                         "max": s["max"], "sum": s["sum"], "n": s["n"]}
                by_key[key] = s
            elif kind == "counter":
                cur["value"] = cur["value"] + s["value"]
            elif kind == "gauge":
                cur["min"] = min(cur["min"], s["min"])
                cur["max"] = max(cur["max"], s["max"])
                cur["sum"] = cur["sum"] + s["sum"]
                cur["n"] = cur["n"] + s["n"]
            else:                        # histogram: exact bucket-wise add
                cur["counts"] = [x + y for x, y in
                                 zip(cur["counts"], s["counts"])]
                cur["sum"] = cur["sum"] + s["sum"]
                cur["count"] = cur["count"] + s["count"]
    return [by_key[k] for k in sorted(by_key)]


def merge_snapshots(a: dict, b: dict) -> dict:
    """Merge two ``Registry.snapshot()`` dicts: counters sum, gauges combine
    (min, max, sum, n), histograms add bucket-wise. Associative and
    commutative, so fleet aggregation order does not matter."""
    out = {}
    for name in sorted(set(a) | set(b)):
        ea, eb = a.get(name), b.get(name)
        if ea is None or eb is None:
            src = ea or eb
            entry = dict(src)
            entry["series"] = _merge_series(src["kind"], src["series"], [])
            out[name] = entry
            continue
        if ea["kind"] != eb["kind"]:
            raise ValueError(f"metric {name!r}: kind mismatch "
                             f"{ea['kind']} vs {eb['kind']}")
        if ea["kind"] == "histogram" and ea["edges"] != eb["edges"]:
            raise ValueError(f"histogram {name!r}: bucket edges differ "
                             f"across snapshots")
        entry = {"kind": ea["kind"], "help": ea["help"] or eb["help"],
                 "series": _merge_series(ea["kind"], ea["series"],
                                         eb["series"])}
        if ea["kind"] == "histogram":
            entry["edges"] = list(ea["edges"])
        out[name] = entry
    return out


# -- module-level default registry ------------------------------------------

_default = Registry()


def get_registry() -> Registry:
    return _default


def counter(name: str, help: str = "") -> Counter:
    return _default.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _default.gauge(name, help)


def histogram(name: str, help: str = "",
              buckets=LATENCY_BUCKETS_S) -> Histogram:
    return _default.histogram(name, help, buckets=buckets)


def snapshot_json(snap: dict) -> str:
    """Deterministic JSON encoding (sorted keys) of a snapshot."""
    return json.dumps(snap, sort_keys=True)
