"""Zero-dependency observability: metrics registry, span tracing, and
multi-host aggregation. See ``registry``/``tracing``/``aggregate`` for the
pieces; the public surface is re-exported here so call sites write
``from repro import obs`` and stay short."""
from repro.obs.registry import (  # noqa: F401
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    Registry,
    counter,
    gauge,
    get_registry,
    hist_quantile,
    histogram,
    merge_snapshots,
    snapshot_json,
)
from repro.obs.tracing import (  # noqa: F401
    emit,
    enable_xprof,
    get_trace_sink,
    set_trace_sink,
    trace_span,
    trace_to,
)
from repro.obs.aggregate import (  # noqa: F401
    DEFAULT_METRICS_PATH,
    dist_snapshot,
    write_snapshot,
)

__all__ = [
    "LATENCY_BUCKETS_S", "Counter", "Gauge", "Histogram", "Registry",
    "counter", "gauge", "get_registry", "hist_quantile", "histogram",
    "merge_snapshots", "snapshot_json",
    "emit", "enable_xprof", "get_trace_sink", "set_trace_sink",
    "trace_span", "trace_to",
    "DEFAULT_METRICS_PATH", "dist_snapshot", "write_snapshot",
]
