"""Multi-host aggregation: one merged metrics report for the whole fleet.

``dist_snapshot()`` turns N per-process registries into ONE snapshot,
identical on every host, using only the existing ``repro.dist`` machinery
(the same shard_map all-gather idiom as the mapped island search — no gRPC
side channel, no extra dependency):

  1. each process serializes its local ``Registry.snapshot()`` to JSON bytes;
  2. two all-gathers over a ("hosts",) mesh spanning every global device —
     first the payload lengths (so all processes agree on one padded width),
     then the padded payload rows themselves (as int32: exact for byte
     values, and the least exotic dtype for the CPU gloo backend);
  3. every host decodes all rows, dedupes by process index (a process with
     k local devices contributes k identical rows) and folds the per-process
     snapshots with ``merge_snapshots`` in process order.

Because the gathered bytes are identical everywhere and the merge is
deterministic, every host computes the SAME aggregate — the property the CI
2-process lane asserts. Counters sum, gauges keep (min, max, sum, n),
histograms add bucket-wise (exact: fixed edges).

``write_snapshot()`` is the process-0 commit: it writes (or name-merges
into) ``artifacts/obs/metrics.json`` so successive drivers in one CI lane —
the search bench, then the serving bench — accumulate into one report the
way ``BENCH_*.json`` rows do.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

from repro.obs.registry import (Registry, get_registry, merge_snapshots)

__all__ = ["dist_snapshot", "write_snapshot", "DEFAULT_METRICS_PATH"]

DEFAULT_METRICS_PATH = "artifacts/obs/metrics.json"

_AXIS = "hosts"
_PAD = 4096          # payload rows padded to a multiple: bounds recompiles
_gather_fns: dict = {}


def _gather_rows(rows):
    """All-gather one (n_devices, L) int32 row per device; every process
    gets the full matrix. Compiled once per (topology, L)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.dist.compat import shard_map

    devs = jax.devices()
    key = (tuple(d.id for d in devs), rows.shape[1])
    if key not in _gather_fns:
        mesh = Mesh(np.array(devs), (_AXIS,))
        shd = NamedSharding(mesh, P(_AXIS))
        fn = jax.jit(shard_map(
            lambda r: jax.lax.all_gather(r[0], _AXIS),
            mesh=mesh, in_specs=(P(_AXIS),), out_specs=P(),
            check_vma=False))

        def run(local_rows):
            # every process fills ALL of its addressable rows with its own
            # payload; make_array_from_callback touches only local shards
            arr = jax.make_array_from_callback(
                local_rows.shape, shd,
                lambda idx: np.ascontiguousarray(local_rows[idx]))
            return np.asarray(fn(arr))

        _gather_fns[key] = run
    return _gather_fns[key](rows)


def _exchange_payload(payload: bytes) -> list:
    """Returns every process's payload bytes, ordered by device id (rows of
    the same process repeat — callers dedupe by the embedded pid)."""
    import jax
    import numpy as np

    n = len(jax.devices())
    lens = np.full((n, 1), len(payload), np.int32)
    all_lens = _gather_rows(lens)[:, 0]
    width = -(-int(all_lens.max()) // _PAD) * _PAD
    rows = np.zeros((n, width), np.int32)
    rows[:, : len(payload)] = np.frombuffer(payload, np.uint8)
    gathered = _gather_rows(rows)
    return [gathered[i, : all_lens[i]].astype(np.uint8).tobytes()
            for i in range(n)]


def dist_snapshot(registry: Optional[Registry] = None, *,
                  force_gather: bool = False) -> dict:
    """Fleet-merged snapshot, identical on every process.

    Single-process runs skip the collectives and return the local snapshot
    (already in canonical mergeable form); ``force_gather=True`` exercises
    the gather path on a single-process multi-device topology (tests)."""
    reg = registry if registry is not None else get_registry()
    local = reg.snapshot()

    import jax
    if jax.process_count() == 1 and not force_gather:
        return merge_snapshots(local, {})   # normalize through the merge

    payload = json.dumps(
        {"pid": jax.process_index(), "snap": local}).encode()
    per_pid: dict = {}
    for raw in _exchange_payload(payload):
        msg = json.loads(raw.decode())
        per_pid.setdefault(int(msg["pid"]), msg["snap"])
    merged: dict = {}
    for pid in sorted(per_pid):
        merged = merge_snapshots(merged, per_pid[pid])
    return merged


def write_snapshot(snapshot: Optional[dict] = None,
                   path=DEFAULT_METRICS_PATH, *,
                   registry: Optional[Registry] = None,
                   merge: bool = True) -> Optional[pathlib.Path]:
    """Write a snapshot to ``path`` (process 0 only; other processes are a
    no-op and return None).

    ``snapshot=None`` takes ``dist_snapshot(registry)`` first — the one-call
    "fleet emits one report" path. With ``merge=True`` an existing file's
    metrics are kept unless this snapshot carries the same name (row-level
    replace, like the BENCH_*.json writers), so sequential drivers in one CI
    lane accumulate into a single report without double counting."""
    if snapshot is None:
        snapshot = dist_snapshot(registry)

    import jax
    if jax.process_count() > 1 and jax.process_index() != 0:
        return None
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    out = snapshot
    if merge and p.exists():
        try:
            prev = json.loads(p.read_text())
        except ValueError:
            prev = {}
        out = {**prev, **snapshot}
        out = {k: out[k] for k in sorted(out)}
    p.write_text(json.dumps(out, indent=1, sort_keys=True))
    return p
