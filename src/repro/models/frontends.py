"""Modality frontend STUBS (per assignment spec: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers generate deterministic synthetic embeddings for smoke tests and
the ShapeDtypeStruct stand-ins used by the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def stub_vision_embeds(key, cfg: ModelConfig, batch: int, n_patches: int = None):
    """Precomputed ViT patch embeddings (B, P, D) — stands in for InternViT."""
    n = n_patches or cfg.frontend_len or 256
    return jax.random.normal(key, (batch, n, cfg.d_model), jnp.dtype(cfg.compute_dtype)) * 0.02


def stub_audio_frames(key, cfg: ModelConfig, batch: int, n_frames: int):
    """Precomputed speech frame embeddings (B, T, D) — stands in for the
    Seamless speech frontend (fbank + conformer downsampling)."""
    return jax.random.normal(key, (batch, n_frames, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype)) * 0.02


def frontend_spec(cfg: ModelConfig, batch: int, length: int):
    """ShapeDtypeStruct stand-in for dry-run input_specs()."""
    return jax.ShapeDtypeStruct((batch, length, cfg.d_model), jnp.dtype(cfg.compute_dtype))
