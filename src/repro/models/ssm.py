"""Mamba2 / SSD (state-space duality) block — chunked scan, TPU-friendly.

Follows the minimal-SSD formulation (Dao & Gu 2024, arXiv:2405.21060):

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D ⊙ x_t

computed chunk-wise: intra-chunk contributions use a quadratic (attention-like)
decay matrix on the MXU; inter-chunk state is a short ``lax.scan`` over chunks.

TP note: the input projection is stored as SEPARATE weights (w_z, w_x, w_B,
w_C, w_dt) rather than one fused in_proj so that the d_inner/head axes shard
cleanly on the "model" mesh axis with no mid-tensor section boundaries
(DESIGN.md §5). It also makes the within-head permutation invariance
(InvarExplore-for-SSM) a pure gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm

__all__ = ["init_ssm", "ssm_forward", "ssm_decode_step", "init_ssm_state"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    g, n = s.n_groups, s.d_state
    conv_dim = di + 2 * g * n
    return di, h, g, n, conv_dim


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    s = cfg.ssm
    di, h, g, n, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 8)
    sd = d ** -0.5
    dt = jnp.exp(jax.random.uniform(ks[0], (h,)) * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "w_z": jax.random.normal(ks[1], (d, di), dtype) * sd,
        "w_x": jax.random.normal(ks[2], (d, di), dtype) * sd,
        "w_B": jax.random.normal(ks[3], (d, g * n), dtype) * sd,
        "w_C": jax.random.normal(ks[4], (d, g * n), dtype) * sd,
        "w_dt": jax.random.normal(ks[5], (d, h), dtype) * sd,
        "conv_x": jax.random.normal(ks[6], (s.conv_width, di), dtype) * s.conv_width ** -0.5,
        "conv_B": jax.random.normal(ks[7], (s.conv_width, g * n), dtype) * s.conv_width ** -0.5,
        "conv_C": jax.random.normal(ks[0], (s.conv_width, g * n), dtype) * s.conv_width ** -0.5,
        "conv_b_x": jnp.zeros((di,), dtype),
        "conv_b_B": jnp.zeros((g * n,), dtype),
        "conv_b_C": jnp.zeros((g * n,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[3], (h,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    L = x.shape[1]
    out = sum(xp[:, i:i + L] * w[i] for i in range(W))
    return out + b


def _ssd_chunked(xh, a, Bm, Cm, chunk, unroll: bool = False):
    """xh: (B,L,H,P) = dt*x; a: (B,L,H) = A*dt; Bm/Cm: (B,L,G,N).

    Returns y: (B,L,H,P) and final state (B,H,P,N).
    """
    B_, L, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    def chunked(t, extra):  # (B, Lp, ...) -> (B, nc, Q, ...)
        return t.reshape((B_, nc, Q) + extra)

    xh_c = chunked(xh, (H, P)).astype(jnp.float32)
    a_c = chunked(a, (H,)).astype(jnp.float32)
    # broadcast groups to heads: (B,nc,Q,G,N) -> (B,nc,Q,H,N)
    Bh = jnp.repeat(chunked(Bm, (G, N)), rep, axis=3).astype(jnp.float32)
    Ch = jnp.repeat(chunked(Cm, (G, N)), rep, axis=3).astype(jnp.float32)

    cum = jnp.cumsum(a_c, axis=2)                      # (B,nc,Q,H)
    # intra-chunk decay matrix: dec[i,j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    dec = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    # y_diag[i] = sum_{j<=i} (C_i·B_j) dec[i,j] u_j
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", Ch, Bh)
    y_diag = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", cb, dec, xh_c)

    # chunk-final states: S_c = sum_j exp(cum_last - cum_j) B_j ⊗ u_j
    dec_s = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,Q,H)
    S_c = jnp.einsum("bckhn,bckh,bckhp->bchpn", Bh, dec_s, xh_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,nc,H)

    def body(h_prev, xs):
        s_c, d_c = xs                                   # (B,H,P,N), (B,H)
        h_new = h_prev * d_c[:, :, None, None] + s_c
        return h_new, h_prev

    s_seq = jnp.moveaxis(S_c, 1, 0)                     # (nc,B,H,P,N)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)             # (nc,B,H)
    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(body, h0, (s_seq, d_seq), unroll=unroll)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,H,P,N)

    # inter-chunk contribution: y_off[i] = exp(cum_i) * C_i · h_prev
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, h_prevs, jnp.exp(cum))
    y = (y_diag + y_off).reshape(B_, Lp, H, P)[:, :L]
    return y, h_final


def _project(p, cfg: ModelConfig, x):
    """x: (B,L,D) -> z (B,L,di), x/B/C (pre-conv), dt (B,L,H)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bs = x @ p["w_B"]
    Cs = x @ p["w_C"]
    dt = x @ p["w_dt"]
    return z, xs, Bs, Cs, dt


def ssm_forward(p, cfg: ModelConfig, x, return_state=False):
    """Full-sequence Mamba2 block body (no residual). x: (B, L, D)."""
    s = cfg.ssm
    di, h, g, n, conv_dim = _dims(cfg)
    B_, L, _ = x.shape
    z, xs, Bs, Cs, dt = _project(p, cfg, x)
    xs_post = jax.nn.silu(_causal_conv(xs, p["conv_x"], p["conv_b_x"]))
    Bs_post = jax.nn.silu(_causal_conv(Bs, p["conv_B"], p["conv_b_B"]))
    Cs_post = jax.nn.silu(_causal_conv(Cs, p["conv_C"], p["conv_b_C"]))
    xi = xs_post.reshape(B_, L, h, s.head_dim)
    Bm = Bs_post.reshape(B_, L, g, n)
    Cm = Cs_post.reshape(B_, L, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    y, final_state = _ssd_chunked(xi * dt[..., None], dt * A[None, None, :], Bm, Cm, s.chunk,
                                  unroll=cfg.unroll_inner)
    y = y + xi.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, L, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    if return_state:
        conv_state = {
            "x": xs[:, -(s.conv_width - 1):, :],
            "B": Bs[:, -(s.conv_width - 1):, :],
            "C": Cs[:, -(s.conv_width - 1):, :],
        }
        return out, {"state": final_state, "conv": conv_state}
    return out


def init_ssm_state(cfg: ModelConfig, batch, dtype=jnp.float32):
    s = cfg.ssm
    di, h, g, n, conv_dim = _dims(cfg)
    w = s.conv_width - 1
    return {
        "state": jnp.zeros((batch, h, s.head_dim, n), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, w, di), dtype),
            "B": jnp.zeros((batch, w, g * n), dtype),
            "C": jnp.zeros((batch, w, g * n), dtype),
        },
    }


def _conv_step(win_prev, new, w, b):
    """Single-position depthwise conv using the cached window."""
    win = jnp.concatenate([win_prev, new[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.sum(win * w[None], axis=1) + b
    return out, win[:, 1:]


def ssm_decode_step(p, cfg: ModelConfig, x, state):
    """Single-token decode. x: (B, 1, D); state from init_ssm_state."""
    s = cfg.ssm
    di, h, g, n, conv_dim = _dims(cfg)
    B_ = x.shape[0]
    z, xs, Bs, Cs, dt = _project(p, cfg, x[:, 0:1])
    xs, Bs, Cs, dt, z = xs[:, 0], Bs[:, 0], Cs[:, 0], dt[:, 0], z[:, 0]
    xo, new_cx = _conv_step(state["conv"]["x"], xs, p["conv_x"], p["conv_b_x"])
    Bo, new_cb = _conv_step(state["conv"]["B"], Bs, p["conv_B"], p["conv_b_B"])
    Co, new_cc = _conv_step(state["conv"]["C"], Cs, p["conv_C"], p["conv_b_C"])
    xi = jax.nn.silu(xo).reshape(B_, h, s.head_dim)
    Bm = jax.nn.silu(Bo).reshape(B_, g, n)
    Cm = jax.nn.silu(Co).reshape(B_, g, n)
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                           # (B,H)
    u = xi.astype(jnp.float32) * dt[..., None]              # (B,H,P)
    new_state = state["state"] * dA[:, :, None, None] + jnp.einsum("bhn,bhp->bhpn", Bh, u)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state) + xi.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"state": new_state,
                 "conv": {"x": new_cx, "B": new_cb, "C": new_cc}}
