"""Composable model configuration covering every assigned architecture.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec stacks.
Exact full-size configs live in ``repro/configs/<arch>.py``; reduced smoke
configs are derived with ``.reduced()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25

    def capacity(self, seq_len: int) -> int:
        """Per-batch-row expert capacity (cumsum/positions are computed per
        row so token dispatch never serializes across the data axis)."""
        c = math.ceil(seq_len * self.top_k / self.num_experts * self.capacity_factor)
        return max(1, c)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab_size: int = 512
    head_dim: int = 0                 # 0 => d_model // n_heads
    activation: str = "silu"          # relu | silu | gelu
    gated_mlp: bool = True
    qk_norm: bool = False
    use_bias: bool = False            # biases on mlp / attn out
    attn_qkv_bias: bool = False       # qwen2-style qkv bias
    pos_emb: str = "rope"             # rope | learned | none
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    max_seq_len: int = 8192           # for learned positions / cache default

    block_pattern: str = "dense"      # dense | moe | ssm | hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_period: int = 6            # hybrid: every Nth block = shared attn+mlp

    encoder_layers: int = 0           # >0 => encoder-decoder
    frontend: str = "none"            # none | vision | audio (stub embeddings)
    frontend_len: int = 0             # patches / frames prepended (vlm) or enc len

    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    attn_chunk: int = 1024            # kv-chunk for blocked online-softmax attention
    vocab_pad_multiple: int = 256
    # Dry-run Δ-trick only: fully unroll layer/inner scans so XLA cost
    # analysis counts every iteration (while bodies are otherwise counted
    # once). Never set for real execution.
    unroll_layers: bool = False
    unroll_inner: bool = False
    # ---- perf-hillclimb knobs (EXPERIMENTS.md §Perf) ----
    remat_policy: str = "full"        # full | dots | none  (train remat)
    attn_softmax_dtype: str = "float32"   # float32 | bfloat16 score pipeline
    gqa_repeat_kv: bool = False       # repeat KV to q-heads pre-attention so
                                      # scores stay head-sharded under TP
    kv_cache_dtype: str = "compute"   # compute | int8 (absmax-scaled KV cache)
    use_flash_decode: bool = False    # route 1-token decode attention through
                                      # the fused Pallas kernel (TPU; interpret
                                      # mode elsewhere)

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.block_pattern == "ssm"

    def hybrid_layout(self) -> Tuple[int, int]:
        """(n_mamba_blocks, n_attn_applications) for hybrid stacks.

        Block i in [0, n_layers) is a shared attention block iff
        i % period == period - 1.
        """
        n_attn = self.n_layers // self.hybrid_period
        return self.n_layers - n_attn, n_attn

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp = d * f + f * d + (d * f if self.gated_mlp else 0)
        per_dense = attn + mlp + 2 * d
        n = 0
        if self.block_pattern == "dense":
            n += self.n_layers * per_dense
        elif self.block_pattern == "moe":
            e = self.moe.num_experts
            n += self.n_layers * (attn + e * mlp + d * e + 2 * d)
        elif self.block_pattern == "ssm":
            n += self.n_layers * self._ssm_block_params()
        elif self.block_pattern == "hybrid":
            n_m, _ = self.hybrid_layout()
            n += n_m * self._ssm_block_params() + per_dense  # one shared attn+mlp block
        if self.is_enc_dec:
            enc_attn = 4 * d * d
            n += self.encoder_layers * (enc_attn + 2 * d * f + 2 * d)
            n += self.n_layers * (attn + 2 * d)  # cross-attention blocks
        n += v * d * (1 if self.tie_embeddings else 2)
        if self.pos_emb == "learned":
            n += self.max_seq_len * d
        return n

    def active_param_count(self) -> int:
        """Activated params per token (= param_count for non-MoE)."""
        if self.block_pattern != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp = d * f + f * d + (d * f if self.gated_mlp else 0)
        k = self.moe.top_k
        act = self.n_layers * (attn + k * mlp + d * self.moe.num_experts + 2 * d)
        act += self.padded_vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return act

    def _ssm_block_params(self) -> int:
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        h = s.n_heads(d)
        g = s.n_groups
        in_proj = d * (2 * di + 2 * g * s.d_state + h)
        conv = s.conv_width * (di + 2 * g * s.d_state)
        out_proj = di * d
        return in_proj + conv + out_proj + 3 * h + di + d

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers,
                         2 if self.block_pattern != "hybrid"
                         else self.hybrid_period + 1),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            head_dim=16 if self.head_dim else 0,
            max_seq_len=256,
            frontend_len=8 if self.frontend != "none" else 0,
            encoder_layers=min(self.encoder_layers, 2),
            moe=dataclasses.replace(self.moe, num_experts=4, top_k=2) if self.moe else None,
            ssm=(dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                     chunk=32) if self.ssm else None),
            remat=False,
            attn_chunk=64,
            param_dtype="float32",
            compute_dtype="float32",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)
