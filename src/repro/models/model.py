"""Model assembly: init / forward / decode for every block pattern.

Layer stacks are scanned (stacked params, one compiled body) so 40-80 layer
models lower to a small HLO. Quantized serving: any 2-D weight leaf may be a
``QTensor`` — it is dequantized *inside* the scan body, so only one layer's
weights are ever materialised (this is where 2-bit serving saves HBM).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as SSM

__all__ = [
    "init_params", "forward", "lm_loss", "init_cache", "decode_step",
    "prefill", "dequant_tree", "lm_head_logits", "quantizable_paths",
]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dequant_tree(tree, dtype=None):
    """Materialise any QTensor leaves (called per scan-slice inside blocks)."""
    def deq(x):
        if isinstance(x, QTensor):
            w = x.dequantize(dtype or jnp.float32)
            return w
        return x
    return jax.tree.map(deq, tree, is_leaf=lambda x: isinstance(x, QTensor))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig, dt, cross=False):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(cfg.d_model, cfg.norm, dt),
        "attn": L.init_attn(ks[0], cfg, dt),
        "ln2": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if cfg.block_pattern == "moe" and not cross:
        p["moe"] = L.init_moe(ks[1], cfg, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dt)
    if cross:
        p["ln_x"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        p["xattn"] = L.init_attn(ks[2], cfg, dt)
    return p


def _init_ssm_block(key, cfg: ModelConfig, dt):
    return {"ln1": L.init_norm(cfg.d_model, cfg.norm, dt), "ssm": SSM.init_ssm(key, cfg, dt)}


def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    V, D = cfg.padded_vocab, cfg.d_model
    params = {"embed": {"tok": jax.random.normal(keys[0], (V, D), dt) * 0.02}}
    if cfg.pos_emb == "learned":
        params["embed"]["pos"] = jax.random.normal(keys[1], (cfg.max_seq_len, D), dt) * 0.02

    if cfg.block_pattern in ("dense", "moe"):
        cross = cfg.is_enc_dec
        params["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, dt, cross=cross), keys[2], cfg.n_layers)
    elif cfg.block_pattern == "ssm":
        params["blocks"] = _stack_init(lambda k: _init_ssm_block(k, cfg, dt), keys[2], cfg.n_layers)
    elif cfg.block_pattern == "hybrid":
        n_m, n_a = cfg.hybrid_layout()
        params["blocks"] = _stack_init(lambda k: _init_ssm_block(k, cfg, dt), keys[2], n_m)
        params["shared"] = _init_dense_block(keys[3], cfg, dt)
    else:
        raise ValueError(cfg.block_pattern)

    if cfg.is_enc_dec:
        enc_cfg = cfg  # same dims; encoder blocks are non-causal dense
        params["enc_blocks"] = _stack_init(
            lambda k: _init_dense_block(k, enc_cfg, dt, cross=False), keys[4], cfg.encoder_layers)
        params["enc_norm"] = L.init_norm(D, cfg.norm, dt)

    params["final_norm"] = L.init_norm(D, cfg.norm, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[5], (D, V), dt) * D ** -0.5
    return params


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------

def _dense_body(pl, cfg: ModelConfig, h, positions, cache=None, cache_index=0,
                enc_h=None, causal=True):
    pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
    a_in = L.apply_norm(h, pl["ln1"], cfg.norm)
    a, new_cache = L.self_attention(pl["attn"], cfg, a_in, positions, causal=causal,
                                    cache=cache, cache_index=cache_index)
    h = h + a
    if "xattn" in pl and enc_h is not None:
        x_in = L.apply_norm(h, pl["ln_x"], cfg.norm)
        kv = L.cross_kv(pl["xattn"], cfg, enc_h)
        h = h + L.cross_attention(pl["xattn"], cfg, x_in, kv)
    m_in = L.apply_norm(h, pl["ln2"], cfg.norm)
    if "moe" in pl:
        h = h + L.moe_ffn(pl["moe"], cfg, m_in)
    else:
        h = h + L.mlp(pl["mlp"], cfg, m_in)
    return h, new_cache


def _dense_body_cached_cross(pl, cfg, h, positions, cache, cache_index, cross_kv):
    """Decode body for enc-dec: cross-attn uses precomputed (k, v)."""
    pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
    a_in = L.apply_norm(h, pl["ln1"], cfg.norm)
    a, new_cache = L.self_attention(pl["attn"], cfg, a_in, positions, causal=True,
                                    cache=cache, cache_index=cache_index)
    h = h + a
    x_in = L.apply_norm(h, pl["ln_x"], cfg.norm)
    h = h + L.cross_attention(pl["xattn"], cfg, x_in, cross_kv)
    m_in = L.apply_norm(h, pl["ln2"], cfg.norm)
    h = h + L.mlp(pl["mlp"], cfg, m_in)
    return h, new_cache


def _ssm_body(pl, cfg: ModelConfig, h, state=None, decode=False):
    pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
    s_in = L.apply_norm(h, pl["ln1"], cfg.norm)
    if decode:
        out, new_state = SSM.ssm_decode_step(pl["ssm"], cfg, s_in, state)
        return h + out, new_state
    return h + SSM.ssm_forward(pl["ssm"], cfg, s_in), None


def _single_kv(cfg: ModelConfig, batch: int, max_len: int, dt):
    """One block's empty KV cache (matches init_cache leaf layout sans stack)."""
    hd = cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads), dt),
                "v_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads), dt)}
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt)}


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        # §Perf iteration 1 (refuted for MoE): batch-dim dots are NOT saved,
        # so MoE expert einsums / attention einsums recompute anyway.
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat_policy == "dots_all":
        # §Perf iteration 2: save EVERY dot output (incl. batched MoE/attn
        # einsums), recompute only the elementwise tail.
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward (training / full-sequence)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, positions):
    h = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if cfg.pos_emb == "learned":
        h = h + jnp.take(params["embed"]["pos"], positions, axis=0)
    return h.astype(jnp.dtype(cfg.compute_dtype))


def lm_head_logits(params, cfg: ModelConfig, h, *, mask_vocab: bool = False):
    """Final norm + (tied or dedicated, possibly QTensor) LM head.

    The one implementation every decode path shares — forward / decode_step /
    prefill here plus the paged serving steps in ``repro.serving``.
    ``mask_vocab=True`` sets padded-vocab columns to -inf (the serving steps'
    convention before argmax/sampling).
    """
    h = L.apply_norm(h, params["final_norm"], cfg.norm)
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    if isinstance(head, QTensor):
        head = head.dequantize(h.dtype)
    logits = h @ head.astype(h.dtype)
    if mask_vocab:
        V = logits.shape[-1]
        if V > cfg.vocab_size:
            logits = jnp.where(jnp.arange(V) < cfg.vocab_size, logits,
                               -jnp.inf)
    return logits


def _run_encoder(params, cfg: ModelConfig, enc_embeds):
    h = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(h.shape[1])

    def body(carry, pl):
        out, _ = _dense_body(pl, cfg, carry, positions, causal=False)
        return out, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["enc_blocks"], unroll=cfg.unroll_layers)
    return L.apply_norm(h, params["enc_norm"], cfg.norm)


def forward(params, cfg: ModelConfig, tokens, *, enc_embeds=None, vision_embeds=None,
            collect_hidden=False):
    """Full-sequence forward -> logits (B, S_total, V_padded).

    vision_embeds (B, P, D) are prepended (VLM); enc_embeds (B, S_enc, D) feed
    the encoder (enc-dec).
    """
    B, S = tokens.shape
    h = embed_tokens(params, cfg, tokens, jnp.arange(S))
    if vision_embeds is not None:
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])
    enc_h = _run_encoder(params, cfg, enc_embeds) if cfg.is_enc_dec else None

    if cfg.block_pattern in ("dense", "moe"):
        def body(carry, pl):
            out, _ = _dense_body(pl, cfg, carry, positions, enc_h=enc_h)
            return out, out if collect_hidden else None
        h, hidden = jax.lax.scan(_maybe_remat(body, cfg), h, params["blocks"],
                                 unroll=cfg.unroll_layers)
    elif cfg.block_pattern == "ssm":
        def body(carry, pl):
            out, _ = _ssm_body(pl, cfg, carry)
            return out, out if collect_hidden else None
        h, hidden = jax.lax.scan(_maybe_remat(body, cfg), h, params["blocks"],
                                 unroll=cfg.unroll_layers)
    elif cfg.block_pattern == "hybrid":
        h, hidden = _hybrid_forward(params, cfg, h, positions, collect_hidden)
    else:
        raise ValueError(cfg.block_pattern)

    logits = lm_head_logits(params, cfg, h)
    if collect_hidden:
        return logits, hidden
    return logits


def _hybrid_forward(params, cfg: ModelConfig, h, positions, collect_hidden):
    """Zamba2-style: every ``period``-th block is a SHARED attn+mlp block."""
    period = cfg.hybrid_period
    n_m, n_a = cfg.hybrid_layout()
    per_group = period - 1
    n_group_m = n_a * per_group
    shared = params["shared"]

    grouped = jax.tree.map(lambda x: x[:n_group_m].reshape((n_a, per_group) + x.shape[1:]),
                           params["blocks"])
    tail = jax.tree.map(lambda x: x[n_group_m:], params["blocks"])

    def mamba_scan(h, stack):
        def body(carry, pl):
            out, _ = _ssm_body(pl, cfg, carry)
            return out, None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, stack, unroll=cfg.unroll_layers)
        return h

    def group_body(carry, group_params):
        h = mamba_scan(carry, group_params)
        h, _ = _dense_body(shared, cfg, h, positions)
        return h, h if collect_hidden else None

    h, hidden = jax.lax.scan(_maybe_remat(group_body, cfg), h, grouped, unroll=cfg.unroll_layers)
    if n_m - n_group_m > 0:
        h = mamba_scan(h, tail)
    return h, hidden


def lm_loss(logits, labels, vocab_size: int, ignore_id: int = -1):
    """Mean next-token CE; positions with label == ignore_id are masked;
    padded vocab ids are masked out of the softmax."""
    V = logits.shape[-1]
    if V > vocab_size:
        mask = jnp.arange(V) < vocab_size
        logits = jnp.where(mask[None, None, :], logits, L.NEG_INF)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    valid = labels != ignore_id
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


# ---------------------------------------------------------------------------
# Decode (KV cache / SSM state)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim

    def kv(n_l):
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
                "v": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
                "k_scale": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads), dt),
                "v_scale": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads), dt),
            }
        return {
            "k": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((n_l, batch, max_len, cfg.n_kv_heads, hd), dt),
        }

    if cfg.block_pattern in ("dense", "moe"):
        cache = kv(cfg.n_layers)
        if cfg.is_enc_dec:
            cache["cross"] = None  # filled at prefill from encoder output
        return cache
    if cfg.block_pattern == "ssm":
        return jax.vmap(lambda _: SSM.init_ssm_state(cfg, batch, dt))(jnp.arange(cfg.n_layers))
    if cfg.block_pattern == "hybrid":
        n_m, n_a = cfg.hybrid_layout()
        return {
            "ssm": jax.vmap(lambda _: SSM.init_ssm_state(cfg, batch, dt))(jnp.arange(n_m)),
            "attn": kv(n_a),
        }
    raise ValueError(cfg.block_pattern)


def decode_step(params, cfg: ModelConfig, tokens, cache, index):
    """One decode step. tokens: (B, 1) int32; index: scalar int32 (position).

    Returns (logits (B, 1, V), new_cache).
    """
    h = embed_tokens(params, cfg, tokens, index + jnp.arange(1))
    positions = index + jnp.arange(1)

    if cfg.block_pattern in ("dense", "moe"):
        cross = cache.get("cross") if isinstance(cache, dict) else None

        def body(carry, xs):
            if cross is not None:
                pl, c, xkv = xs
                out, nc = _dense_body_cached_cross(pl, cfg, carry, positions, c, index, xkv)
            else:
                pl, c = xs
                out, nc = _dense_body(pl, cfg, carry, positions, cache=c, cache_index=index)
            return out, nc

        kv_slices = {k: v for k, v in cache.items() if k != "cross"}
        if cross is not None:
            h, new_kv = jax.lax.scan(body, h, (params["blocks"], kv_slices, cross),
                                     unroll=cfg.unroll_layers)
            new_cache = {**new_kv, "cross": cross}
        else:
            h, new_kv = jax.lax.scan(body, h, (params["blocks"], kv_slices),
                                     unroll=cfg.unroll_layers)
            new_cache = new_kv
    elif cfg.block_pattern == "ssm":
        def body(carry, xs):
            pl, st = xs
            out, ns = _ssm_body(pl, cfg, carry, state=st, decode=True)
            return out, ns
        h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache), unroll=cfg.unroll_layers)
    elif cfg.block_pattern == "hybrid":
        h, new_cache = _hybrid_decode(params, cfg, h, positions, cache, index)
    else:
        raise ValueError(cfg.block_pattern)

    return lm_head_logits(params, cfg, h), new_cache


def _hybrid_decode(params, cfg: ModelConfig, h, positions, cache, index):
    period = cfg.hybrid_period
    n_m, n_a = cfg.hybrid_layout()
    per_group = period - 1
    n_group_m = n_a * per_group
    shared = params["shared"]

    grouped_p = jax.tree.map(lambda x: x[:n_group_m].reshape((n_a, per_group) + x.shape[1:]),
                             params["blocks"])
    tail_p = jax.tree.map(lambda x: x[n_group_m:], params["blocks"])
    grouped_s = jax.tree.map(lambda x: x[:n_group_m].reshape((n_a, per_group) + x.shape[1:]),
                             cache["ssm"])
    tail_s = jax.tree.map(lambda x: x[n_group_m:], cache["ssm"])

    def mamba_scan(h, stack, states):
        def body(carry, xs):
            pl, st = xs
            out, ns = _ssm_body(pl, cfg, carry, state=st, decode=True)
            return out, ns
        return jax.lax.scan(body, h, (stack, states), unroll=cfg.unroll_layers)

    def group_body(carry, xs):
        gp, gs, ac = xs
        h, new_gs = mamba_scan(carry, gp, gs)
        h, new_ac = _dense_body(shared, cfg, h, positions, cache=ac, cache_index=index)
        return h, (new_gs, new_ac)

    h, (new_grouped_s, new_attn) = jax.lax.scan(
        group_body, h, (grouped_p, grouped_s, cache["attn"]),
        unroll=cfg.unroll_layers)
    if n_m - n_group_m > 0:
        h, new_tail_s = mamba_scan(h, tail_p, tail_s)
    else:
        new_tail_s = tail_s
    new_ssm = jax.tree.map(
        lambda a, b: jnp.concatenate([a.reshape((n_group_m,) + a.shape[2:]), b], axis=0),
        new_grouped_s, new_tail_s)
    return h, {"ssm": new_ssm, "attn": new_attn}


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *, enc_embeds=None,
            vision_embeds=None):
    """Process a prompt, building the cache. Returns (logits, cache).

    For simplicity the prefill recomputes per-layer K/V into a fresh cache via
    the same block bodies with cache writes at index 0.
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    h = embed_tokens(params, cfg, tokens, jnp.arange(S))
    if vision_embeds is not None:
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])

    if cfg.block_pattern in ("dense", "moe"):
        if cfg.is_enc_dec:
            enc_h = _run_encoder(params, cfg, enc_embeds)

            def xkv_of(pl):
                pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
                return L.cross_kv(pl["xattn"], cfg, enc_h)
            cross = jax.lax.map(xkv_of, params["blocks"])

            def body(carry, xs):
                pl, c, xkv = xs
                out, nc = _dense_body_cached_cross(pl, cfg, carry, positions, c, 0, xkv)
                return out, nc
            kv = {k: v for k, v in cache.items() if k != "cross"}
            h, new_kv = jax.lax.scan(body, h, (params["blocks"], kv, cross),
                                     unroll=cfg.unroll_layers)
            new_cache = {**new_kv, "cross": cross}
        else:
            def body(carry, xs):
                pl, c = xs
                out, nc = _dense_body(pl, cfg, carry, positions, cache=c, cache_index=0)
                return out, nc
            h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache),
                                        unroll=cfg.unroll_layers)
    elif cfg.block_pattern == "ssm":
        # full-sequence forward capturing each layer's final SSD + conv state
        def body(carry, xs):
            pl, st = xs
            pl = dequant_tree(pl, jnp.dtype(cfg.compute_dtype))
            s_in = L.apply_norm(carry, pl["ln1"], cfg.norm)
            out, fs = SSM.ssm_forward(pl["ssm"], cfg, s_in, return_state=True)
            return carry + out, fs
        h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache), unroll=cfg.unroll_layers)
    elif cfg.block_pattern == "hybrid":
        h, new_cache = _hybrid_prefill(params, cfg, h, positions, max_len)
    else:
        raise ValueError(cfg.block_pattern)

    return lm_head_logits(params, cfg, h), new_cache


def _hybrid_prefill(params, cfg: ModelConfig, h, positions, max_len: int):
    """Full-sequence hybrid pass capturing SSM states + shared-attn KV cache."""
    period = cfg.hybrid_period
    n_m, n_a = cfg.hybrid_layout()
    per_group = period - 1
    n_group_m = n_a * per_group
    shared = params["shared"]
    B = h.shape[0]
    dt = jnp.dtype(cfg.compute_dtype)

    grouped_p = jax.tree.map(lambda x: x[:n_group_m].reshape((n_a, per_group) + x.shape[1:]),
                             params["blocks"])
    tail_p = jax.tree.map(lambda x: x[n_group_m:], params["blocks"])

    def mamba_scan_state(h, stack):
        def body(carry, pl):
            pl = dequant_tree(pl, dt)
            s_in = L.apply_norm(carry, pl["ln1"], cfg.norm)
            out, fs = SSM.ssm_forward(pl["ssm"], cfg, s_in, return_state=True)
            return carry + out, fs
        return jax.lax.scan(body, h, stack, unroll=cfg.unroll_layers)

    empty_kv = _single_kv(cfg, B, max_len, dt)

    def group_body(carry, gp):
        h = carry
        h, gs = mamba_scan_state(h, gp)
        h, nc = _dense_body(shared, cfg, h, positions, cache=empty_kv, cache_index=0)
        return h, (gs, nc)

    h, (grouped_states, attn_caches) = jax.lax.scan(group_body, h, grouped_p,
                                                    unroll=cfg.unroll_layers)
    if n_m - n_group_m > 0:
        h, tail_states = mamba_scan_state(h, tail_p)
        ssm_states = jax.tree.map(
            lambda a, b: jnp.concatenate(
                [a.reshape((n_group_m,) + a.shape[2:]), b], axis=0),
            grouped_states, tail_states)
    else:
        ssm_states = jax.tree.map(
            lambda a: a.reshape((n_group_m,) + a.shape[2:]), grouped_states)
    return h, {"ssm": ssm_states, "attn": attn_caches}


# ---------------------------------------------------------------------------
# Quantizable-leaf selection
# ---------------------------------------------------------------------------

_QUANT_KEYS = ("wq", "wk", "wv", "wo", "up", "gate", "down", "w_z", "w_x", "out_proj")
_SKIP_SUBSTR = ("embed", "ln", "norm", "router", "conv", "bias")


def quantizable_paths(params) -> list:
    """Paths (tuples of keys) of weight leaves the PTQ methods quantize."""
    out = []

    def walk(tree, path):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
            return
        key = path[-1]
        if key in _QUANT_KEYS and not any(s in str(p) for p in path for s in _SKIP_SUBSTR):
            if hasattr(tree, "ndim") and tree.ndim >= 2:
                out.append(path)

    walk(params, ())
    return out
