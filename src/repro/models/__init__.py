from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.models.model import (
    init_params, forward, lm_loss, init_cache, decode_step, prefill,
    dequant_tree, quantizable_paths,
)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig",
    "init_params", "forward", "lm_loss", "init_cache", "decode_step",
    "prefill", "dequant_tree", "quantizable_paths",
]
