"""Shared neural building blocks: norms, RoPE, blocked attention, MLP, MoE."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * w + b


def apply_norm(x, p, kind):
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


def init_norm(d, kind, dtype):
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def activation_fn(name):
    return {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim, theta):
    """positions: (..., S) int -> cos/sin (..., S, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, Dh); cos/sin: (B, S, Dh//2) or (S, Dh//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (online-softmax) attention — memory O(S·chunk), GQA-aware
# ---------------------------------------------------------------------------

def blocked_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                      chunk: int = 1024, unroll: bool = False,
                      softmax_dtype=jnp.float32, repeat_kv: bool = False,
                      k_scale=None, v_scale=None):
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh). Returns (B, Sq, Hq, Dh).

    Streams KV in chunks with an online softmax (flash-attention recurrence),
    so the (Sq, Sk) logit matrix is never materialised — required for the
    32k/500k shapes. ``q_offset`` is the absolute position of q[0] (decode);
    ``kv_len`` masks cache positions >= kv_len.
    """
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if repeat_kv and Hkv < Hq:
        # §Perf: keep scores HEAD-SHARDED under TP — the (Hkv, rep) reshape of
        # a head-sharded q axis defeats GSPMD propagation; repeating KV to Hq
        # heads costs (rep/model_shards)x KV reads but shards all score math.
        rep0 = Hq // Hkv
        k = jnp.repeat(k, rep0, axis=2)
        v = jnp.repeat(v, rep0, axis=2)
        if k_scale is not None:
            k_scale = jnp.repeat(k_scale, rep0, axis=2)
            v_scale = jnp.repeat(v_scale, rep0, axis=2)
        Hkv = Hq
    rep = Hq // Hkv
    sdt = jnp.dtype(softmax_dtype)
    scale = Dh ** -0.5
    qg = q.reshape(B, Sq, Hkv, rep, Dh).astype(sdt) * jnp.asarray(scale, sdt)

    chunk = min(chunk, Sk)
    if Sk % chunk != 0:  # pad KV to a chunk multiple; padding is masked out
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        Sk_pad = Sk + pad
    else:
        Sk_pad = Sk
    n_chunks = Sk_pad // chunk
    if kv_len is None:
        kv_len = Sk
    kc = k.reshape(B, n_chunks, chunk, Hkv, Dh).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dh).swapaxes(0, 1)
    scale_xs = None
    if k_scale is not None:  # int8 KV cache: per-(pos, head) absmax scales
        scale_xs = (k_scale.reshape(B, n_chunks, chunk, Hkv).swapaxes(0, 1),
                    v_scale.reshape(B, n_chunks, chunk, Hkv).swapaxes(0, 1))

    q_pos = q_offset + jnp.arange(Sq)
    neg = jnp.asarray(NEG_INF if sdt == jnp.float32 else -3e38, jnp.float32).astype(sdt)

    def body(carry, xs):
        m, l, acc = carry
        if scale_xs is not None:
            kb, vb, ksb, vsb, start = xs
            kb = kb.astype(sdt) * ksb[..., None].astype(sdt)
            vb = vb.astype(sdt) * vsb[..., None].astype(sdt)
        else:
            kb, vb, start = xs
        # logits: (B, Hkv, rep, Sq, chunk)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kb.astype(sdt),
                       preferred_element_type=sdt)
        k_pos = start + jnp.arange(chunk)
        mask = (k_pos[None, :] < kv_len)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask[None, None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        p = jnp.exp(s - m_new[..., None].astype(sdt))  # (m is small: no Sk dim)
        p = jnp.where(mask[None, None, None], p, jnp.zeros((), sdt))
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1).astype(jnp.float32)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p, vb.astype(sdt),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Sq, Dh), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    xs = (kc, vc) + (scale_xs if scale_xs is not None else ()) + (starts,)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs, unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA, optional qk-norm / rope / biases)
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    sd = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * sd,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * sd,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * sd,
        "wo": jax.random.normal(ks[3], (hq * hd, d), dtype) * (hq * hd) ** -0.5,
    }
    if cfg.attn_qkv_bias or cfg.use_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.use_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def quantize_kv(k, v):
    """int8 KV quantize-on-write: absmax scale per (..., head) over head_dim.

    The single source of the cache quantization convention — the contiguous
    cache (``self_attention``) and the paged pool writer
    (``repro.serving.decode``) must stay bit-identical or their documented
    tolerances diverge. Returns (k_int8, v_int8, k_scale, v_scale).
    """
    ks = jnp.max(jnp.abs(k), axis=-1) / 127.0 + 1e-8
    vs = jnp.max(jnp.abs(v), axis=-1) / 127.0 + 1e-8
    kq = jnp.round(k / ks[..., None]).astype(jnp.int8)
    vq = jnp.round(v / vs[..., None]).astype(jnp.int8)
    return kq, vq, ks, vs


def attn_qkv(p, cfg: ModelConfig, x, positions):
    """Project + rope. Returns q, k, v as (B, S, H, Dh)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attn_out(p, x_attn, cfg: ModelConfig):
    B, S = x_attn.shape[:2]
    out = x_attn.reshape(B, S, -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


def self_attention(p, cfg: ModelConfig, x, positions, *, causal=True, cache=None,
                   cache_index=None):
    """Full self-attention block body (no norm / residual).

    cache: optional dict {"k": (B, Smax, Hkv, Dh), "v": ..., } updated at
    ``cache_index`` (decode path).
    """
    q, k, v = attn_qkv(p, cfg, x, positions)
    sdt = jnp.dtype(cfg.attn_softmax_dtype)
    kw = dict(chunk=cfg.attn_chunk, unroll=cfg.unroll_inner,
              softmax_dtype=sdt, repeat_kv=cfg.gqa_repeat_kv)
    if cache is not None:
        def upd(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), cache_index, axis=1)

        if cfg.kv_cache_dtype == "int8":
            # quantize on write, dequant per chunk at read
            kq, vq, ks, vs = quantize_kv(k, v)
            new_cache = {"k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
                         "k_scale": upd(cache["k_scale"], ks),
                         "v_scale": upd(cache["v_scale"], vs)}
            kw.update(k_scale=new_cache["k_scale"], v_scale=new_cache["v_scale"])
        else:
            new_cache = {"k": upd(cache["k"], k), "v": upd(cache["v"], v)}
        kv_len = cache_index + k.shape[1]
        if cfg.use_flash_decode and q.shape[1] == 1:
            out = _flash_decode_attention(cfg, q, new_cache, kv_len)
        else:
            out = blocked_attention(q, new_cache["k"], new_cache["v"],
                                    causal=causal, q_offset=cache_index,
                                    kv_len=kv_len, **kw)
        return attn_out(p, out, cfg), new_cache
    out = blocked_attention(q, k, v, causal=causal, **kw)
    return attn_out(p, out, cfg), None


def _flash_decode_attention(cfg: ModelConfig, q, cache, kv_len):
    """Route a single decode token through the fused Pallas kernel
    (kernels/flash_decode.py) — GQA heads repeated into the kernel call,
    int8 caches dequantized in-register."""
    from repro.kernels import flash_decode  # local import: kernels are optional
    B, _, Hq, Dh = q.shape
    k, v = cache["k"], cache["v"]
    ks, vs = cache.get("k_scale"), cache.get("v_scale")
    rep = Hq // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        if ks is not None:
            ks = jnp.repeat(ks, rep, axis=2)
            vs = jnp.repeat(vs, rep, axis=2)
    # kernel expects a static kv_len; decode at a traced index falls back to
    # full-length attention with zero-filled (masked-by-softmax-zero) slots:
    # unwritten cache rows are zeros -> exp(0-scores) contributes; so instead
    # mask via the scales path when quantized, else pass kv_len=None only if
    # the cache is fully written. We keep correctness by computing over the
    # whole buffer with -inf masking inside the kernel when kv_len is static.
    kv_len_static = int(kv_len) if not isinstance(kv_len, jax.core.Tracer) else None
    if kv_len_static is None:
        # dynamic position: use the jnp online-softmax path (kernel needs a
        # static mask bound) — still benefits from int8 dequant-in-chunk.
        return blocked_attention(q, cache["k"], cache["v"], causal=True,
                                 q_offset=kv_len - 1, kv_len=kv_len,
                                 chunk=cfg.attn_chunk,
                                 softmax_dtype=jnp.dtype(cfg.attn_softmax_dtype),
                                 k_scale=ks if rep == 1 else cache.get("k_scale"),
                                 v_scale=vs if rep == 1 else cache.get("v_scale"))
    out = flash_decode(q[:, 0], k, v, ks, vs, kv_len=kv_len_static,
                       chunk=min(512, k.shape[1]))
    return out[:, None].astype(q.dtype)


def cross_attention(p, cfg: ModelConfig, x, enc_kv):
    """Decoder cross-attention; enc_kv = (k, v) precomputed from encoder."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k, v = enc_kv
    out = blocked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk, unroll=cfg.unroll_inner)
    return attn_out(p, out, cfg)


def cross_kv(p, cfg: ModelConfig, enc_h):
    B, S, _ = enc_h.shape
    hd = cfg.resolved_head_dim
    k = enc_h @ p["wk"]
    v = enc_h @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, S, cfg.n_kv_heads, hd), v.reshape(B, S, cfg.n_kv_heads, hd))


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "up": jax.random.normal(ks[0], (d, f), dtype) * d ** -0.5,
        "down": jax.random.normal(ks[1], (f, d), dtype) * f ** -0.5,
    }
    if cfg.gated_mlp:
        p["gate"] = jax.random.normal(ks[2], (d, f), dtype) * d ** -0.5
    if cfg.use_bias:
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
        if cfg.gated_mlp:
            p["b_gate"] = jnp.zeros((f,), dtype)
    return p


def mlp(p, cfg: ModelConfig, x):
    act = activation_fn(cfg.activation)
    up = x @ p["up"]
    if "b_up" in p:
        up = up + p["b_up"]
    if cfg.gated_mlp:
        g = x @ p["gate"]
        if "b_gate" in p:
            g = g + p["b_gate"]
        h = act(g) * up
    else:
        h = act(up)
    out = h @ p["down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ---------------------------------------------------------------------------
# MoE (top-k routing, per-row capacity, scatter dispatch -> EP all-to-all)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * d ** -0.5,
        "up": jax.random.normal(ks[1], (e, d, f), dtype) * d ** -0.5,
        "down": jax.random.normal(ks[2], (e, f, d), dtype) * f ** -0.5,
    }
    if cfg.gated_mlp:
        p["gate"] = jax.random.normal(ks[3], (e, d, f), dtype) * d ** -0.5
    return p


def moe_ffn(p, cfg: ModelConfig, x):
    """x: (B, S, D). Positions/capacity computed PER ROW so the token cumsum
    never crosses the data-sharded batch axis (no serializing collectives);
    the (B, E, C, D) dispatch buffer resharded b:data -> e:model is the
    all-to-all under expert parallelism.
    """
    B, S, D = x.shape
    mcfg = cfg.moe
    E, K = mcfg.num_experts, mcfg.top_k
    C = mcfg.capacity(S)
    act = activation_fn(cfg.activation)

    logits = x @ p["router"]                       # (B, S, E)
    gate_w, gate_idx = jax.lax.top_k(logits, K)    # (B, S, K)
    gate_w = jax.nn.softmax(gate_w.astype(jnp.float32), axis=-1).astype(x.dtype)

    # slot layout: (B, S*K)
    e_idx = gate_idx.reshape(B, S * K)
    onehot = jax.nn.one_hot(e_idx, E, dtype=jnp.int32)          # (B, S*K, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                     # (B, S*K, E)
    pos = jnp.take_along_axis(pos_all, e_idx[..., None], axis=2)[..., 0]
    keep = (pos < C)
    pos_c = jnp.clip(pos, 0, C - 1)

    x_slots = jnp.repeat(x, K, axis=1)                           # (B, S*K, D)
    x_slots = x_slots * keep[..., None].astype(x.dtype)
    b_iota = jnp.arange(B)[:, None] * jnp.ones((1, S * K), jnp.int32)
    buf = jnp.zeros((B, E, C, D), x.dtype).at[b_iota, e_idx, pos_c].add(x_slots)

    up = jnp.einsum("becd,edf->becf", buf, p["up"])
    if cfg.gated_mlp:
        g = jnp.einsum("becd,edf->becf", buf, p["gate"])
        h = act(g) * up
    else:
        h = act(up)
    out_buf = jnp.einsum("becf,efd->becd", h, p["down"])

    out_slots = out_buf[b_iota, e_idx, pos_c] * keep[..., None].astype(x.dtype)
    out = out_slots.reshape(B, S, K, D) * gate_w[..., None]
    return jnp.sum(out, axis=2)


def moe_aux_loss(p, cfg: ModelConfig, x):
    """Load-balancing auxiliary loss (Switch-style), used in training."""
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    E = cfg.moe.num_experts
    _, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(frac_tokens * frac_probs)
