"""Paged single-token decode attention: gather KV pages via a block table.

Continuous batching stores the KV cache as a global pool of fixed-size pages
(``k_pages``/``v_pages``: (N_pages, page_size, Hkv, Dh)) plus one block table
per sequence mapping logical page slots to physical page ids. This kernel
computes one decode token of attention per sequence WITHOUT materialising a
contiguous per-sequence cache: the block table and sequence lengths are
scalar-prefetched (SMEM), so each grid step's BlockSpec index map DMAs exactly
one physical page HBM→VMEM, and the online-softmax state (m, l, acc) stays in
VMEM across the page axis of the grid — the paged analogue of
``flash_decode.py``.

    out[b,h] = softmax(q[b,h] · K[pages(b),h%]ᵀ / sqrt(Dh)) · V[pages(b),h%]

GQA is handled inside the index map (query head h reads KV head h // rep), so
the page pool is never repeated. Fully-masked pages (slot index at or past
``ceil(seq_len / page_size)``) are skipped with ``pl.when`` and their block
index is clamped to the last live page so the dead steps issue no fresh DMA —
short sequences in deep pools pay only for their live pages. Pages may be int8 with per-(slot, head)
absmax scales (the serving cache layout); dequantization happens in-register
per page. With ``normalize=False`` the kernel returns the raw partial stats
(acc, m, l) instead of the normalized output — the exact log-sum-exp partials
``repro.dist.attention.merge_partials`` merges across sequence shards, so a
sequence-sharded cache can be paged per shard.

Two grids cover the GQA axis:

- ``paged_decode_pallas`` — grid (B, H, P): one query head per grid step.
  Under GQA every query head re-DMAs its KV head's page, so each live page
  crosses HBM→VMEM ``rep = H // Hkv`` times per token.
- ``paged_decode_gqa_pallas`` — grid (B, Hkv, P): one KV HEAD per grid step.
  The page is loaded ONCE and all ``rep`` query heads of the group are
  batched against it in VMEM ((rep, psz) score tile on the MXU), cutting
  decode's dominant HBM term — KV page reads — by the GQA ratio. Query heads
  are grouped h // rep = KV head, so the (1, rep, Dh) q block is contiguous.

With ``pages_per_block > 1`` the fused kernel adds a MULTI-PAGE INNER AXIS:
grid (B, Hkv, ceil(P / MP), MP). Each inner step stages one DMA'd page into
a (MP, psz, Dh) VMEM scratch tile and only the LAST inner step runs the
(rep, MP*psz) score matmul + online-softmax update. For small ``rep`` the
per-page (rep, psz) matmul is far below MXU granularity, so the per-page
grid serialises tiny matmuls behind each page's DMA; batching MP pages per
update lets Pallas's inner-axis pipelining overlap the next pages' DMA with
one better-shaped matmul. ``pages_per_block=1`` is the default and keeps the
original single-page grid bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_pallas", "paged_decode_gqa_pallas"]

NEG = -1e30


def _kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            o_ref, m_ref, l_ref, *, page_size, quantized, normalize):
    b = pl.program_id(0)
    p = pl.program_id(2)
    # pages at or past ceil(seq_len / page_size) are fully masked: skip their
    # compute entirely (their softmax contribution is exactly zero, so the
    # running (o, m, l) state is untouched — the equivalence test_paged.py
    # pins). The index maps clamp dead slots to the last live page, so the
    # grid's block index does not change across dead steps and Pallas elides
    # the HBM→VMEM copy — short sequences in deep pools stop paying for dead
    # blocks. (A sequence with seq_len == 0 keeps one "live" page whose slots
    # are all masked; its output stays the zero init.)
    n_live = jnp.maximum((sl_ref[b] + page_size - 1) // page_size, 1)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(p < n_live)
    def _compute():
        q = q_ref[0, 0, :].astype(jnp.float32)               # (Dh,)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)           # (page_size, Dh)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            kb = kb * ks_ref[0, :, 0][:, None].astype(jnp.float32)
            vb = vb * vs_ref[0, :, 0][:, None].astype(jnp.float32)

        dh = q.shape[0]
        s = (kb @ q) * (dh ** -0.5)                          # (page_size,)
        pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
        mask = pos < sl_ref[b]
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        prob = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        o_ref[0, 0, :] = o_ref[0, 0, :] * corr + prob @ vb
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_prev * corr + jnp.sum(prob)

    if normalize:
        @pl.when(p == pl.num_programs(2) - 1)
        def _finish():
            o_ref[0, 0, :] = o_ref[0, 0, :] / jnp.maximum(l_ref[0, 0], 1e-30)


def paged_decode_pallas(q, k_pages, v_pages, block_tables, seq_lens,
                        k_scale=None, v_scale=None, *, normalize: bool = True,
                        interpret: bool = False):
    """q: (B, H, Dh); k/v_pages: (N, page_size, Hkv, Dh) f32/bf16 or int8
    (+ scales (N, page_size, Hkv)); block_tables: (B, P) int32 physical page
    ids; seq_lens: (B,) int32.

    Block-table entries past a sequence's last used page may be arbitrary
    VALID page ids (the batcher pads with page 0): those positions are masked
    by ``seq_lens``. Returns (B, H, Dh) f32, or the unnormalized partial
    stats (acc (B, H, Dh), m (B, H), l (B, H)) when ``normalize=False``.
    """
    B, H, Dh = q.shape
    n_pages, page_size, Hkv, _ = k_pages.shape
    P = block_tables.shape[1]
    rep = H // Hkv
    quantized = k_scale is not None
    if not quantized:  # uniform kernel arity, same idiom as flash_decode
        k_scale = jnp.ones((n_pages, page_size, Hkv), jnp.float32)
        v_scale = jnp.ones((n_pages, page_size, Hkv), jnp.float32)

    def _live_page(bt, sl, b, p):
        # clamp dead page slots (p >= ceil(len/psz)) to the last live page:
        # the block index repeats across consecutive dead grid steps, so no
        # fresh DMA is issued for pages the kernel will skip with pl.when.
        n_live = jnp.maximum((sl[b] + page_size - 1) // page_size, 1)
        return bt[b, jnp.minimum(p, n_live - 1)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, P),
        in_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, p, bt, sl: (b, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  h // rep, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  h // rep, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, h, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  h // rep)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, h, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  h // rep)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, p, bt, sl: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, p, bt, sl: (b, h)),
            pl.BlockSpec((1, 1), lambda b, h, p, bt, sl: (b, h)),
        ],
    )
    out, m, l = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, quantized=quantized,
                          normalize=normalize),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages, k_scale, v_scale)
    if normalize:
        return out
    return out, m, l


def _kernel_gqa(bt_ref, sl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                o_ref, m_ref, l_ref, *, page_size, quantized, normalize):
    b = pl.program_id(0)
    p = pl.program_id(2)
    # same dead-page skip as the per-query-head kernel: pages at or past
    # ceil(seq_len / page_size) contribute exactly zero, and the index maps
    # clamp their block index so the skipped steps issue no fresh DMA.
    n_live = jnp.maximum((sl_ref[b] + page_size - 1) // page_size, 1)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(p < n_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (rep, Dh)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)       # (page_size, Dh)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            kb = kb * ks_ref[0, :, 0][:, None].astype(jnp.float32)
            vb = vb * vs_ref[0, :, 0][:, None].astype(jnp.float32)

        dh = q.shape[-1]
        # ONE page read serves the whole query-head group: (rep, page_size)
        s = (q @ kb.T) * (dh ** -0.5)
        pos = p * page_size + jax.lax.iota(jnp.int32, page_size)
        mask = pos < sl_ref[b]
        s = jnp.where(mask[None, :], s, NEG)

        m_prev = m_ref[0]                                # (rep,)
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        prob = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        o_ref[0] = o_ref[0] * corr[:, None] + prob @ vb
        m_ref[0] = m_new
        l_ref[0] = l_prev * corr + jnp.sum(prob, axis=-1)

    if normalize:
        @pl.when(p == pl.num_programs(2) - 1)
        def _finish():
            o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def _kernel_gqa_mp(bt_ref, sl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                   o_ref, m_ref, l_ref, k_buf, v_buf, *, page_size,
                   pages_per_block, quantized, normalize):
    b = pl.program_id(0)
    blk = pl.program_id(2)                       # outer page-block
    i = pl.program_id(3)                         # inner page within block
    mp = pages_per_block
    n_live = jnp.maximum((sl_ref[b] + page_size - 1) // page_size, 1)
    p = blk * mp + i                             # logical page slot

    @pl.when((blk == 0) & (i == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # stage this inner step's page into the scratch tile (dequantized f32);
    # dead pages are ZEROED, not skipped — their positions are masked out of
    # the softmax below, but a zero row costs nothing while stale scratch
    # content could be NaN-poisoned garbage that 0-weight cannot cancel
    @pl.when(p < n_live)
    def _stage():
        kb = k_ref[0, :, 0, :].astype(jnp.float32)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            kb = kb * ks_ref[0, :, 0][:, None].astype(jnp.float32)
            vb = vb * vs_ref[0, :, 0][:, None].astype(jnp.float32)
        k_buf[i] = kb
        v_buf[i] = vb

    @pl.when(p >= n_live)
    def _stage_dead():
        k_buf[i] = jnp.zeros_like(k_buf[i])
        v_buf[i] = jnp.zeros_like(v_buf[i])

    # one online-softmax update per PAGE BLOCK: the (rep, mp*psz) matmul
    # replaces mp undersized (rep, psz) ones, and runs while the next
    # block's pages are already in flight on the inner grid axis
    @pl.when((i == mp - 1) & (blk * mp < n_live))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (rep, Dh)
        dh = q.shape[-1]
        kk = k_buf[...].reshape(mp * page_size, -1)      # (mp*psz, Dh)
        vv = v_buf[...].reshape(mp * page_size, -1)
        s = (q @ kk.T) * (dh ** -0.5)                    # (rep, mp*psz)
        pos = blk * mp * page_size + jax.lax.iota(jnp.int32, mp * page_size)
        mask = pos < sl_ref[b]
        s = jnp.where(mask[None, :], s, NEG)

        m_prev = m_ref[0]
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        prob = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        o_ref[0] = o_ref[0] * corr[:, None] + prob @ vv
        m_ref[0] = m_new
        l_ref[0] = l_prev * corr + jnp.sum(prob, axis=-1)

    if normalize:
        @pl.when((blk == pl.num_programs(2) - 1) & (i == mp - 1))
        def _finish():
            o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def paged_decode_gqa_pallas(q, k_pages, v_pages, block_tables, seq_lens,
                            k_scale=None, v_scale=None, *,
                            normalize: bool = True, interpret: bool = False,
                            pages_per_block: int = 1):
    """Fused-GQA paged decode: same contract as ``paged_decode_pallas``
    (q (B, H, Dh) over (N, page_size, Hkv, Dh) pools, block-table gather,
    optional int8 scales, optional LSE partials) with a (B, Hkv, P) grid —
    each KV head's page is DMA'd once and its ``H // Hkv`` query heads are
    reduced against it in VMEM.

    ``pages_per_block > 1`` switches to the multi-page inner-axis grid
    (B, Hkv, ceil(P / MP), MP): pages stage into a VMEM scratch tile and one
    (rep, MP*psz) matmul per block overlaps the next pages' DMA — the small-
    ``rep`` regime where per-page matmuls are below MXU granularity.
    ``pages_per_block=1`` keeps the original grid bit-for-bit.
    """
    B, H, Dh = q.shape
    n_pages, page_size, Hkv, _ = k_pages.shape
    P = block_tables.shape[1]
    if H % Hkv != 0:
        raise ValueError(f"H={H} not a multiple of Hkv={Hkv}")
    rep = H // Hkv
    quantized = k_scale is not None
    if not quantized:
        k_scale = jnp.ones((n_pages, page_size, Hkv), jnp.float32)
        v_scale = jnp.ones((n_pages, page_size, Hkv), jnp.float32)

    def _live_page(bt, sl, b, p):
        n_live = jnp.maximum((sl[b] + page_size - 1) // page_size, 1)
        return bt[b, jnp.minimum(p, n_live - 1)]

    if pages_per_block > 1:
        return _gqa_multipage_call(
            q, k_pages, v_pages, block_tables, seq_lens, k_scale, v_scale,
            normalize=normalize, interpret=interpret,
            pages_per_block=pages_per_block, quantized=quantized,
            live_page=_live_page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=[
            # q block = the KV head's whole query-head group (contiguous
            # because query head h belongs to KV head h // rep)
            pl.BlockSpec((1, rep, Dh), lambda b, g, p, bt, sl: (b, g, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, g, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  g, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, g, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  g, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  g)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, p, bt, sl: (_live_page(bt, sl, b, p), 0,
                                                  g)),
        ],
        out_specs=[
            pl.BlockSpec((1, rep, Dh), lambda b, g, p, bt, sl: (b, g, 0)),
            pl.BlockSpec((1, rep), lambda b, g, p, bt, sl: (b, g)),
            pl.BlockSpec((1, rep), lambda b, g, p, bt, sl: (b, g)),
        ],
    )
    out, m, l = pl.pallas_call(
        functools.partial(_kernel_gqa, page_size=page_size,
                          quantized=quantized, normalize=normalize),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages, k_scale, v_scale)
    if normalize:
        return out
    return out, m, l


def _gqa_multipage_call(q, k_pages, v_pages, block_tables, seq_lens, k_scale,
                        v_scale, *, normalize, interpret, pages_per_block,
                        quantized, live_page):
    """The (B, Hkv, n_blocks, MP) grid behind ``pages_per_block > 1``."""
    B, H, Dh = q.shape
    page_size = k_pages.shape[1]
    Hkv = k_pages.shape[2]
    rep = H // Hkv
    P = block_tables.shape[1]
    mp = pages_per_block
    n_blocks = -(-P // mp)

    def kv_map(b, g, blk, i, bt, sl):
        # the inner axis walks one page per step; dead slots clamp to the
        # last live page so consecutive dead steps issue no fresh DMA
        return (live_page(bt, sl, b, blk * mp + i), 0, g, 0)

    def sc_map(b, g, blk, i, bt, sl):
        return (live_page(bt, sl, b, blk * mp + i), 0, g)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_blocks, mp),
        in_specs=[
            pl.BlockSpec((1, rep, Dh), lambda b, g, blk, i, bt, sl: (b, g, 0)),
            pl.BlockSpec((1, page_size, 1, Dh), kv_map),
            pl.BlockSpec((1, page_size, 1, Dh), kv_map),
            pl.BlockSpec((1, page_size, 1), sc_map),
            pl.BlockSpec((1, page_size, 1), sc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, rep, Dh), lambda b, g, blk, i, bt, sl: (b, g, 0)),
            pl.BlockSpec((1, rep), lambda b, g, blk, i, bt, sl: (b, g)),
            pl.BlockSpec((1, rep), lambda b, g, blk, i, bt, sl: (b, g)),
        ],
        scratch_shapes=[
            pltpu.VMEM((mp, page_size, Dh), jnp.float32),   # staged K pages
            pltpu.VMEM((mp, page_size, Dh), jnp.float32),   # staged V pages
        ],
    )
    out, m, l = pl.pallas_call(
        functools.partial(_kernel_gqa_mp, page_size=page_size,
                          pages_per_block=mp, quantized=quantized,
                          normalize=normalize),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages, k_scale, v_scale)
    if normalize:
        return out
    return out, m, l
