"""Fused group quant->dequant roundtrip Pallas TPU kernel.

This is the discrete search's inner primitive (Algorithm 1 evaluates
``fake_quant(T(θ))`` per proposal). Naively it is 4 HBM passes
(min/max reduce, scale/zero, round, dequant); fused it is ONE VMEM pass:
each (bg·G × bn) tile computes its group min/max with a lane-local VPU
reduction (groups are contiguous along the K axis and never straddle tiles),
derives scale/zero, rounds, clips and dequantizes in-register.

Outputs the roundtripped weights plus the per-group scale/zero (the packing
path reuses them without a second pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["group_quant_pallas"]


def _kernel(w_ref, fq_ref, scale_ref, zero_ref, *, bits, group, bg):
    q_max = float((1 << bits) - 1)
    w = w_ref[...].astype(jnp.float32)            # (bg*G, bn)
    bn = w.shape[1]
    wg = w.reshape(bg, group, bn)
    wmax = jnp.max(wg, axis=1)                    # (bg, bn)
    wmin = jnp.min(wg, axis=1)
    scale = jnp.maximum((wmax - wmin) / q_max, 1e-8)
    zero = jnp.clip(jnp.round(-wmin / scale), 0.0, q_max)
    q = jnp.clip(jnp.round(wg / scale[:, None]) + zero[:, None], 0.0, q_max)
    fq = (q - zero[:, None]) * scale[:, None]
    fq_ref[...] = fq.reshape(bg * group, bn).astype(fq_ref.dtype)
    scale_ref[...] = scale
    zero_ref[...] = zero


def group_quant_pallas(w, *, bits: int, group: int, bg: int = 4, bn: int = 256,
                       interpret: bool = False):
    """w: (K, N) -> (fq (K, N), scale (K//G, N), zero (K//G, N)).

    Tile = (bg·G, bn): bg groups per tile so the VMEM working set stays
    small while rows remain group-aligned.
    """
    K, N = w.shape
    n_groups = K // group
    bg = min(bg, n_groups)
    bn = min(bn, N)
    assert K % group == 0 and n_groups % bg == 0 and N % bn == 0
    grid = (n_groups // bg, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group, bg=bg),
        grid=grid,
        in_specs=[pl.BlockSpec((bg * group, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bg * group, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, N), w.dtype),
            jax.ShapeDtypeStruct((n_groups, N), jnp.float32),
            jax.ShapeDtypeStruct((n_groups, N), jnp.float32),
        ],
        interpret=interpret,
    )(w)
