"""Fused single-token decode attention over a (possibly int8) KV cache.

The §Perf hillclimb on yi-6b × decode_32k showed the decode memory term is
dominated by score/correction tensors and cache reads; this kernel is the
structural fix on real TPUs: stream the cache HBM→VMEM chunk by chunk,
dequantize int8 codes in-register, and keep the online-softmax state
(m, l, acc) entirely in VMEM across the sequence grid axis — zero HBM
traffic beyond the cache itself and the (B, H, Dh) output.

    out[b,h] = softmax(q[b,h]·K[b,:,h]ᵀ / sqrt(Dh)) · V[b,:,h]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_decode_pallas"]

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref, *,
            chunk, kv_len, quantized):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)                  # (Dh,)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)              # (chunk, Dh)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        kb = kb * ks_ref[0, :, 0][:, None].astype(jnp.float32)
        vb = vb * vs_ref[0, :, 0][:, None].astype(jnp.float32)

    dh = q.shape[0]
    s = (kb @ q) * (dh ** -0.5)                             # (chunk,)
    pos = c * chunk + jax.lax.iota(jnp.int32, chunk)
    mask = pos < kv_len
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)            # (chunk,)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p)
    o_ref[0, 0, :] = o_ref[0, 0, :] * corr + p @ vb
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    # final normalization on the last chunk
    @pl.when(c == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0, :] = o_ref[0, 0, :] / jnp.maximum(l_ref[0, 0], 1e-30)


def flash_decode_pallas(q, k, v, k_scale=None, v_scale=None, *, kv_len=None,
                        chunk: int = 512, interpret: bool = False):
    """q: (B, H, Dh); k/v: (B, S, H, Dh) bf16/f32 or int8 (+ scales (B, S, H)).

    Returns (B, H, Dh) f32. GQA callers repeat KV heads first (cheap in VMEM).
    """
    B, H, Dh = q.shape
    S = k.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    quantized = k_scale is not None
    if not quantized:  # uniform arity for the kernel
        k_scale = jnp.ones((B, S, H), jnp.float32)
        v_scale = jnp.ones((B, S, H), jnp.float32)
    if kv_len is None:
        kv_len = S
    grid = (B, H, S // chunk)
    out, m, l = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, kv_len=kv_len,
                          quantized=quantized),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, chunk, 1, Dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, Dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, k_scale, v_scale)
    return out
