"""Fused dequant-matmul Pallas TPU kernel: x @ dequant(packed W).

Weight-only ultra-low-bit serving is HBM-bandwidth-bound: at 2 bits + g128
the packed weights are ~7.5x smaller than bf16. The win only materialises if
dequantization happens AFTER the HBM->VMEM stream — so this kernel unpacks
(shift/mask in VREGs), dequantizes ((q - z) * s) and feeds the MXU per
(bm × bk) · (bk × bn) tile, accumulating over the K grid axis. Weight HBM
traffic drops by the packing factor vs. a dense bf16 matmul.

Tiling constraints (checked in ops.py):
  - bk % group_size == 0 and bk % vals_per_word == 0 (scale/zero and packed
    tiles stay row-aligned),
  - bm/bn multiples of 8/128 for MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_matmul_pallas"]


def _kernel(x_ref, packed_ref, scale_ref, zero_ref, o_ref, *, bits, group, bk):
    vpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = packed_ref[...]                     # (bk//vpw, bn) uint32
    # unpack slot i -> original row w*vpw + i : stack along axis 1, reshape
    parts = [((packed >> jnp.uint32(i * bits)) & mask).astype(jnp.float32)
             for i in range(vpw)]
    codes = jnp.stack(parts, axis=1).reshape(bk, packed.shape[1])
    scale = scale_ref[...]                       # (bk//group, bn)
    zero = zero_ref[...]
    s = jnp.repeat(scale, group, axis=0)
    z = jnp.repeat(zero, group, axis=0)
    w = (codes - z) * s                          # dequantized (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def quant_matmul_pallas(x, packed, scale, zero, *, bits: int, group: int,
                        bm: int = 128, bk: int = 512, bn: int = 256,
                        interpret: bool = False):
    """x: (M, K) f32/bf16; packed: (K//vpw, N) uint32; scale/zero: (K//G, N).

    Returns (M, N) f32. Shape constraints are validated by ops.quant_matmul
    (which also pads / falls back to the reference path).
    """
    M, K = x.shape
    N = packed.shape[1]
    vpw = 32 // bits
    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    assert bk % group == 0 and bk % vpw == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // vpw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, packed, scale, zero)
