"""Fused invariant-transform + group fake-quant Pallas TPU kernel.

The search's inner primitive is ``fake_quant(T(θ))``: apply the candidate
(π, s, φ) transform to a unit's FFN weights, then group-quantize. Unfused
that is two full HBM round trips per proposal — materialize the transformed
fp32 weights, then re-read them to quantize. Fused it is ONE pass: each
weight strip is DMA'd to VMEM once, rotated (block-diagonal Givens pairs),
scaled, permuted and group-fake-quantized in-register, and only the
roundtripped weights (plus per-group scale/zero, reusable by the packing
path) go back to HBM.

Two layouts, matching ``core.invariance.apply_transform_ffn``:

- ``mode="up"``   — w (D, F): transform acts on the F *columns* (rotate →
  ×s → permute), quant groups run along the D rows. Tile = (bg·G, F): a full
  F strip so the arbitrary column permutation resolves inside VMEM.
- ``mode="down"`` — w (F, D): transform acts on the F *rows* (rotate → ÷s →
  permute), quant groups run along the F rows — here the permutation
  reshuffles the group axis itself (group membership changes), which is why
  transform and quant cannot be split into independent passes. Tile =
  (F, bn): a full F strip per column block.

The permutation is an arbitrary gather, so the transformed (F) axis must be
VMEM-resident per tile; the wrapper in ``ops.py`` falls back to the jnp
reference when the strip would not fit. ``kernels/ref.py`` carries the
oracle (``transform_quant_ref``); interpret-mode parity is pinned in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["transform_quant_pallas"]


def _rotate_scale_cols(w, phi, s):
    """(rows, F) -> rotated (pairs (2i, 2i+1) of columns) and scaled."""
    rows, f = w.shape
    wp = w.reshape(rows, f // 2, 2)
    c, sn = jnp.cos(phi), jnp.sin(phi)
    a, b = wp[:, :, 0], wp[:, :, 1]
    ra = c[None, :] * a - sn[None, :] * b
    rb = sn[None, :] * a + c[None, :] * b
    return jnp.stack([ra, rb], axis=2).reshape(rows, f) * s[None, :]


def _rotate_scale_rows(w, phi, s_inv):
    """(F, cols) -> rotated (pairs of rows) and scaled by 1/s."""
    f, cols = w.shape
    wp = w.reshape(f // 2, 2, cols)
    c, sn = jnp.cos(phi), jnp.sin(phi)
    a, b = wp[:, 0], wp[:, 1]
    ra = c[:, None] * a - sn[:, None] * b
    rb = sn[:, None] * a + c[:, None] * b
    return jnp.stack([ra, rb], axis=1).reshape(f, cols) * s_inv[:, None]


def _group_fq(t, bits, group):
    """(rows, cols) -> fake-quant roundtrip with groups along rows.

    Same closed forms as ``core.quant`` (q_min = 0), so the fused output is
    bit-compatible with ``fake_quant``.
    """
    q_max = float((1 << bits) - 1)
    rows, cols = t.shape
    tg = t.reshape(rows // group, group, cols)
    wmax = jnp.max(tg, axis=1)
    wmin = jnp.min(tg, axis=1)
    scale = jnp.maximum((wmax - wmin) / q_max, 1e-8)
    zero = jnp.clip(jnp.round(-wmin / scale), 0.0, q_max)
    q = jnp.clip(jnp.round(tg / scale[:, None]) + zero[:, None], 0.0, q_max)
    fq = (q - zero[:, None]) * scale[:, None]
    return fq.reshape(rows, cols), scale, zero


def _kernel_up(pi_ref, s_ref, phi_ref, w_ref, fq_ref, scale_ref, zero_ref, *,
               bits, group):
    w = w_ref[...].astype(jnp.float32)               # (bg*G, F)
    t = _rotate_scale_cols(w, phi_ref[0, :], s_ref[0, :])
    t = jnp.take(t, pi_ref[0, :], axis=1)            # column permutation
    fq, scale, zero = _group_fq(t, bits, group)
    fq_ref[...] = fq.astype(fq_ref.dtype)
    scale_ref[...] = scale
    zero_ref[...] = zero


def _kernel_down(pi_ref, s_ref, phi_ref, w_ref, fq_ref, scale_ref, zero_ref, *,
                 bits, group):
    w = w_ref[...].astype(jnp.float32)               # (F, bn)
    t = _rotate_scale_rows(w, phi_ref[0, :], 1.0 / s_ref[0, :])
    t = jnp.take(t, pi_ref[0, :], axis=0)            # row permutation
    fq, scale, zero = _group_fq(t, bits, group)
    fq_ref[...] = fq.astype(fq_ref.dtype)
    scale_ref[...] = scale
    zero_ref[...] = zero


def transform_quant_pallas(w, pi, s, phi, *, bits: int, group: int, mode: str,
                           bg: int = 4, bn: int = 128,
                           interpret: bool = False):
    """Fused (π, s, φ)-transform + group fake-quant.

    mode="up":   w (D, F) -> (fq (D, F), scale (D//G, F), zero (D//G, F))
    mode="down": w (F, D) -> (fq (F, D), scale (F//G, D), zero (F//G, D))
    pi (F,) int32; s (F,) f32; phi (F//2,) f32.
    """
    K, N = w.shape
    f = N if mode == "up" else K                     # transformed axis length
    assert pi.shape == (f,) and s.shape == (f,) and phi.shape == (f // 2,)
    assert K % group == 0
    n_groups = K // group
    pi2 = pi.astype(jnp.int32)[None, :]
    s2 = s.astype(jnp.float32)[None, :]
    phi2 = phi.astype(jnp.float32)[None, :]
    vec_specs = [
        pl.BlockSpec((1, f), lambda *idx: (0, 0)),
        pl.BlockSpec((1, f), lambda *idx: (0, 0)),
        pl.BlockSpec((1, f // 2), lambda *idx: (0, 0)),
    ]
    if mode == "up":
        bg = min(bg, n_groups)
        assert n_groups % bg == 0
        grid = (n_groups // bg,)
        kernel = functools.partial(_kernel_up, bits=bits, group=group)
        in_spec = pl.BlockSpec((bg * group, f), lambda i: (i, 0))
        out_specs = [
            pl.BlockSpec((bg * group, f), lambda i: (i, 0)),
            pl.BlockSpec((bg, f), lambda i: (i, 0)),
            pl.BlockSpec((bg, f), lambda i: (i, 0)),
        ]
    elif mode == "down":
        bn = min(bn, N)
        assert N % bn == 0
        grid = (N // bn,)
        kernel = functools.partial(_kernel_down, bits=bits, group=group)
        in_spec = pl.BlockSpec((K, bn), lambda j: (0, j))
        out_specs = [
            pl.BlockSpec((K, bn), lambda j: (0, j)),
            pl.BlockSpec((n_groups, bn), lambda j: (0, j)),
            pl.BlockSpec((n_groups, bn), lambda j: (0, j)),
        ]
    else:
        raise ValueError(f"mode must be 'up' or 'down', got {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=vec_specs + [in_spec],
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((K, N), w.dtype),
            jax.ShapeDtypeStruct((n_groups, N), jnp.float32),
            jax.ShapeDtypeStruct((n_groups, N), jnp.float32),
        ],
        interpret=interpret,
    )(pi2, s2, phi2, w)
