"""Pallas TPU kernels for the perf-critical compute of ultra-low-bit serving:

- ``quant_matmul``: fused dequant (packed 1-8 bit) + MXU matmul — the serving
  hot loop; cuts weight HBM traffic by the packing factor.
- ``group_quant``: fused group quant->dequant roundtrip — the discrete
  search's inner primitive (one VMEM pass instead of four HBM passes).
- ``transform_quant``: fused (π, s, φ) invariant transform + group
  fake-quant — the population search's per-proposal hot path; one VMEM pass
  instead of materialize-transformed-weights-then-quantize (two full HBM
  round trips).
- ``flash_decode`` / ``paged_decode``: fused one-token decode attention over
  a contiguous (flash) or block-table-paged (paged) KV cache; the paged
  variant scalar-prefetches the block table so continuous batching reads
  only live pages.

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` wraps them with
jit + CPU interpret-mode fallback; tests sweep shapes/dtypes against the
oracles.
"""
from repro.kernels.ops import (quant_matmul, group_quant, flash_decode,
                               paged_decode, transform_quant, on_tpu)

__all__ = ["quant_matmul", "group_quant", "flash_decode", "paged_decode",
           "transform_quant", "on_tpu"]
