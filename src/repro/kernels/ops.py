"""Jit'd public wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; elsewhere (this CPU container) they
execute in ``interpret=True`` mode for correctness, or fall back to the
pure-jnp reference when a shape violates the tiling constraints.
"""
from __future__ import annotations

import dataclasses
import functools

import jax

from repro.kernels import ref
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.group_quant import group_quant_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.paged_decode import (paged_decode_gqa_pallas,
                                        paged_decode_pallas)
from repro.kernels.transform_quant import transform_quant_pallas

__all__ = ["quant_matmul", "group_quant", "flash_decode", "paged_decode",
           "transform_quant", "tq_plan", "TQPlan", "on_tpu"]

# VMEM budget for one transform_quant full-F strip. The kernel holds an
# input strip AND a same-size fq output strip, and both revolve per grid
# step so Pallas double-buffers each: ~4x the strip bytes must fit in the
# ~16MB core VMEM. Past this the wrapper falls back to the jnp reference.
_TQ_STRIP_BYTES = 3 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TQPlan:
    """Pure tiling/VMEM plan for one ``transform_quant`` call site.

    ``ok`` mirrors the wrapper's runtime guard exactly; ``reason`` names the
    first violated constraint when ``ok`` is False (consumed by the static
    Pallas-budget checker so lint reports say *why* a config falls back).
    """

    ok: bool
    strip_bytes: int
    bg: int          # group-block rows (mode="up"; 0 otherwise)
    bn: int          # N-block cols (mode="down"; 0 otherwise)
    n_groups: int
    f: int           # transformed-axis length (N for "up", K for "down")
    reason: str = ""


def tq_plan(K: int, N: int, *, group: int, mode: str) -> TQPlan:
    """Plan the fused transform+fake-quant kernel for a (K, N) fp32 weight.

    This is the single source of truth for the ``_TQ_STRIP_BYTES`` VMEM
    budget and the grid/block divisibility constraints: ``transform_quant``
    consults it at trace time to pick Pallas vs the jnp reference, and
    ``repro.analysis``'s pallas-budget checker replays it at lint time over
    every config in the zoo.
    """
    f = N if mode == "up" else K
    n_groups = K // group if K % group == 0 else 0
    if mode == "up":
        bg = 4 if n_groups % 4 == 0 else (2 if n_groups % 2 == 0 else 1)
        strip = bg * group * f * 4
        bn = 0
    else:
        bg = 0
        bn = 128 if N % 128 == 0 else (N if N <= 128 else 0)
        strip = K * max(bn, 1) * 4
    ok = (n_groups > 0 and f % 2 == 0 and strip <= _TQ_STRIP_BYTES
          and (mode == "up" or bn > 0))
    reason = ""
    if not ok:
        if n_groups <= 0:
            reason = f"K={K} not divisible by group={group}"
        elif f % 2 != 0:
            reason = f"transformed axis f={f} is odd"
        elif strip > _TQ_STRIP_BYTES:
            reason = (f"VMEM strip {strip}B > _TQ_STRIP_BYTES "
                      f"{_TQ_STRIP_BYTES}B")
        else:
            reason = f"mode=down N={N} has no 128-divisible block"
    return TQPlan(ok=ok, strip_bytes=strip, bg=bg, bn=bn,
                  n_groups=n_groups, f=f, reason=reason)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tileable_matmul(M, K, N, bits, group):
    vpw = 32 // bits
    return (K % group == 0 and K % vpw == 0 and M % 8 == 0 and N % 128 == 0
            and group % vpw in (0,) or group >= vpw)


@functools.partial(jax.jit, static_argnames=("bits", "group", "use_pallas"))
def quant_matmul(x, packed, scale, zero, *, bits: int, group: int,
                 use_pallas: bool = True):
    """x (M, K) @ dequant(packed (K//vpw, N)) -> (M, N) f32.

    The serving path's hot matmul: weights stream packed (2-bit: 16 codes per
    uint32 word), dequantized tile-by-tile in VMEM.
    """
    M, K = x.shape
    N = packed.shape[1]
    vpw = 32 // bits
    ok = (K % group == 0 and K % vpw == 0 and M % 8 == 0 and N % 128 == 0)
    if not (use_pallas and ok):
        return ref.quant_matmul_ref(x, packed, scale, zero, bits, group)
    bk = K
    for cand in (512, 256, 128):
        if K % cand == 0 and cand % group == 0 and cand % vpw == 0:
            bk = cand
            break
    bm = 128 if M % 128 == 0 else 8
    bn = 256 if N % 256 == 0 else 128
    return quant_matmul_pallas(x, packed, scale, zero, bits=bits, group=group,
                               bm=bm, bk=bk, bn=bn, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("kv_len", "chunk", "use_pallas"))
def flash_decode(q, k, v, k_scale=None, v_scale=None, *, kv_len=None,
                 chunk: int = 512, use_pallas: bool = True):
    """Fused one-token decode attention over a bf16 or int8 KV cache."""
    S = k.shape[1]
    ok = S % min(chunk, S) == 0
    if not (use_pallas and ok):
        return ref.flash_decode_ref(q, k, v, k_scale, v_scale, kv_len)
    return flash_decode_pallas(q, k, v, k_scale, v_scale, kv_len=kv_len,
                               chunk=chunk, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("normalize", "use_pallas",
                                             "fused_gqa",
                                             "gqa_pages_per_block"))
def paged_decode(q, k_pages, v_pages, block_tables, seq_lens, k_scale=None,
                 v_scale=None, *, normalize: bool = True,
                 use_pallas: bool = True, fused_gqa: bool = True,
                 gqa_pages_per_block: int = 1):
    """Paged one-token decode attention over a block-table page pool.

    The continuous-batching hot path: q (B, H, Dh) attends over the pages
    named by ``block_tables`` (B, P) in the global (N, page_size, Hkv, Dh)
    pool, masked to per-sequence ``seq_lens``. ``normalize=False`` returns
    the (acc, m, l) partials for the cross-shard LSE merge.

    With ``fused_gqa`` (the default) GQA shapes (H > Hkv) route to the
    (B, Hkv, P)-grid kernel that loads each KV head's page once for its
    whole query-head group — decode HBM reads drop by the GQA ratio. MHA
    shapes (H == Hkv) always use the per-query-head grid, so pre-GQA callers
    see bit-identical outputs. ``gqa_pages_per_block > 1`` further batches
    the fused kernel's online-softmax update over page blocks (the
    multi-page inner grid axis — DMA of the next pages overlaps one
    MXU-shaped (rep, MP*psz) matmul); the default 1 keeps the single-page
    grid bit-for-bit.
    """
    if not use_pallas:
        return ref.paged_decode_ref(q, k_pages, v_pages, block_tables,
                                    seq_lens, k_scale, v_scale,
                                    normalize=normalize)
    H, Hkv = q.shape[1], k_pages.shape[2]
    if fused_gqa and H > Hkv and H % Hkv == 0:
        return paged_decode_gqa_pallas(q, k_pages, v_pages, block_tables,
                                       seq_lens, k_scale, v_scale,
                                       normalize=normalize,
                                       interpret=not on_tpu(),
                                       pages_per_block=gqa_pages_per_block)
    return paged_decode_pallas(q, k_pages, v_pages, block_tables, seq_lens,
                               k_scale, v_scale, normalize=normalize,
                               interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("bits", "group", "mode", "use_pallas"))
def transform_quant(w, pi, s, phi, *, bits: int, group: int, mode: str,
                    use_pallas: bool = True):
    """Fused (π, s, φ) invariant transform + group fake-quant roundtrip.

    The search engine's fused hot path: one VMEM pass instead of
    materializing the transformed fp32 weights and re-reading them to
    quantize (two HBM round trips per proposal). ``mode="up"`` transforms
    columns of a (D, F) weight; ``mode="down"`` transforms rows of a (F, D)
    weight (there the permutation reshuffles the quant-group axis itself, so
    the passes genuinely cannot be split). Returns (fq, scale, zero).
    """
    K, N = w.shape
    plan = tq_plan(K, N, group=group, mode=mode)
    if not (use_pallas and plan.ok):
        return ref.transform_quant_ref(w, pi, s, phi, bits=bits, group=group,
                                       mode=mode)
    return transform_quant_pallas(w, pi, s, phi, bits=bits, group=group,
                                  mode=mode, bg=plan.bg or 4,
                                  bn=plan.bn or 128, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("bits", "group", "use_pallas"))
def group_quant(w, *, bits: int, group: int, use_pallas: bool = True):
    """Fused fake-quant roundtrip (the search inner primitive).

    Returns (fq (K, N), scale (K//G, N), zero (K//G, N)).
    """
    K, N = w.shape
    ok = (K % group == 0 and N % 128 == 0)
    if not (use_pallas and ok):
        return ref.group_quant_ref(w, bits, group)
    n_groups = K // group
    bg = 4 if n_groups % 4 == 0 else (2 if n_groups % 2 == 0 else 1)
    bn = 256 if N % 256 == 0 else 128
    return group_quant_pallas(w, bits=bits, group=group, bg=bg, bn=bn,
                              interpret=not on_tpu())
