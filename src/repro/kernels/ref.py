"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.invariance import apply_rotation_cols, apply_rotation_rows
from repro.core.quant import (QuantConfig, compute_qparams, quantize_codes,
                              dequantize_codes, unpack_codes)

__all__ = ["quant_matmul_ref", "group_quant_ref", "dequant_ref",
           "flash_decode_ref", "paged_decode_ref", "transform_quant_ref"]


def flash_decode_ref(q, k, v, k_scale=None, v_scale=None, kv_len=None):
    """Dense one-token attention oracle. q (B,H,Dh); k/v (B,S,H,Dh)."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None].astype(jnp.float32)
        vf = vf * v_scale[..., None].astype(jnp.float32)
    dh = q.shape[-1]
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf) * dh ** -0.5
    if kv_len is not None:
        mask = jnp.arange(k.shape[1]) < kv_len
        s = jnp.where(mask[None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vf)


def paged_decode_ref(q, k_pages, v_pages, block_tables, seq_lens,
                     k_scale=None, v_scale=None, normalize=True):
    """Dense paged-attention oracle: gather pages, then plain softmax.

    q (B, H, Dh); k/v_pages (N, page_size, Hkv, Dh) [+ scales
    (N, page_size, Hkv)]; block_tables (B, P) int32; seq_lens (B,) int32.
    Returns (B, H, Dh), or the (acc, m, l) log-sum-exp partials when
    ``normalize=False`` (the dist merge contract).

    The ONE oracle for both paged-decode grids: the per-query-head kernel
    and the fused-GQA (B, Hkv, P) variant compute the same math, so
    ``paged_decode_gqa_pallas`` parity is pinned against this function
    (``repeat``-ing KV to H heads here IS the unfused read pattern the
    fused grid eliminates).
    """
    B, H, Dh = q.shape
    _, page_size, Hkv, _ = k_pages.shape
    P = block_tables.shape[1]
    kf = k_pages[block_tables].astype(jnp.float32)     # (B, P, psz, Hkv, Dh)
    vf = v_pages[block_tables].astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[block_tables][..., None].astype(jnp.float32)
        vf = vf * v_scale[block_tables][..., None].astype(jnp.float32)
    kf = kf.reshape(B, P * page_size, Hkv, Dh)
    vf = vf.reshape(B, P * page_size, Hkv, Dh)
    if Hkv < H:  # GQA: repeat KV heads to the query head count
        kf = jnp.repeat(kf, H // Hkv, axis=2)
        vf = jnp.repeat(vf, H // Hkv, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf) * Dh ** -0.5
    mask = jnp.arange(P * page_size)[None, :] < seq_lens[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    if not normalize:
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhs,bshd->bhd", p, vf)
        return acc, m, l
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vf)


def dequant_ref(packed, scale, zero, bits: int, group_size: int, k: int):
    """packed (K_pad//vpw, N) uint32 -> dense weights (K, N) f32.

    Dequantizes at the PADDED length (scale/zero rows cover K_pad when
    lcm(group, vals_per_word) padding was applied, e.g. 3-bit), then slices.
    """
    cfg = QuantConfig(bits=bits, group_size=group_size)
    k_pad = packed.shape[0] * (32 // bits)
    codes = unpack_codes(packed, bits, k_pad)
    return dequantize_codes(codes, scale, zero, cfg)[:k]


def quant_matmul_ref(x, packed, scale, zero, bits: int, group_size: int):
    """x (M, K) @ dequant(packed) -> (M, N) f32."""
    k = x.shape[1]
    w = dequant_ref(packed, scale, zero, bits, group_size, k)
    return x.astype(jnp.float32) @ w


def group_quant_ref(w, bits: int, group_size: int):
    """Fused quant->dequant roundtrip; returns (fq, scale, zero)."""
    cfg = QuantConfig(bits=bits, group_size=group_size)
    scale, zero = compute_qparams(w.astype(jnp.float32), cfg)
    codes = quantize_codes(w.astype(jnp.float32), scale, zero, cfg)
    fq = dequantize_codes(codes, scale, zero, cfg, out_dtype=w.dtype)
    return fq, scale, zero


def transform_quant_ref(w, pi, s, phi, *, bits: int, group: int, mode: str):
    """Materialize-then-quantize composition of ``apply_transform_ffn``'s
    up/down branches with the group fake-quant roundtrip — the oracle for the
    fused ``transform_quant`` kernel. Returns (fq, scale, zero)."""
    w = w.astype(jnp.float32)
    if mode == "up":        # w (D, F): rotate -> x s -> permute on columns
        t = apply_rotation_cols(w, phi) * s[None, :]
        t = t[:, pi]
    elif mode == "down":    # w (F, D): rotate -> / s -> permute on rows
        t = apply_rotation_rows(w, phi) * (1.0 / s)[:, None]
        t = t[pi, :]
    else:
        raise ValueError(f"mode must be 'up' or 'down', got {mode!r}")
    return group_quant_ref(t, bits, group)
