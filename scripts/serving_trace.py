"""Run the many-tenant shared-prefix serving trace and print the sharing win.

    PYTHONPATH=src python scripts/serving_trace.py

Thin CLI over ``benchmarks.serving_bench``: replays the deterministic trace
with the prefix cache off and on, asserts outputs token-identical + no page
leaked + >= 50% of prefill tokens aliased, and writes the rows (including
p50/p99 TTFT) to ``artifacts/benchmarks/BENCH_serving.json``.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from benchmarks.serving_bench import run  # noqa: E402

if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
    print(f"# rows written to artifacts/benchmarks/BENCH_serving.json",
          file=sys.stderr)
