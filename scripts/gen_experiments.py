"""Regenerate EXPERIMENTS.md tables from artifacts (dry-run + benchmarks).

    PYTHONPATH=src python scripts/gen_experiments.py
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.report import load_cells, dryrun_table, roofline_table, summary  # noqa: E402

HEAD = """# EXPERIMENTS — InvarExplore reproduction + multi-pod framework

All numbers in this file are produced by code in this repository:
- benchmark tables: `PYTHONPATH=src python -m benchmarks.run` (JSON in `artifacts/benchmarks/`)
- dry-run / roofline: `PYTHONPATH=src python -m repro.launch.dryrun --all`
  (JSON per cell in `artifacts/dryrun/`)
- this file: `PYTHONPATH=src python scripts/gen_experiments.py`

Hardware target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI);
runtime here is a 1-core CPU container, so §Paper-claims numbers are from an
in-harness-trained OPT-family model on a synthetic corpus (DESIGN.md §7) and
§Dry-run/§Roofline numbers are derived from compiled XLA artifacts
(ShapeDtypeStruct lowering — no allocation, no execution).

## §Paper-claims (faithful-reproduction validation)

Paper Table 1 behaviour on a REAL (trained) model, 2-bit group quantization —
from `artifacts/benchmarks/table1.json` (run `-m benchmarks.run --only table1`):

{table1}

Validated claims (asserted in `benchmarks/` and `tests/test_system.py`):
1. 2-bit RTN degrades a trained model catastrophically (ppl x2 here;
   orders of magnitude at paper scale).
2. Every calibrated baseline (GPTQ/AWQ/OmniQuant-lite) beats RTN.
3. **+InvarExplore improves EVERY base method it stacks on** (the paper's
   central add-on claim) — largest gain on AWQ, smallest on OmniQuant
   (already near its optimum), matching the paper's ordering of gains.
4. Table 2 ablation: permutation (strongest here) and scaling each improve
   over AWQ; rotation alone is ~neutral at this scale (σ_r=1e-5 moves are
   tiny on a 4-layer model); the COMBINED transform is best — the paper's
   synergy claim.
5. Table 3: 1-bit collapses even with IE (which still halves its ppl);
   2-bit gains most; 3-bit is saturated (IE ~neutral, as in the paper);
   finer groups better at a small bits/param cost.
6. Table 4: activation matching helps (best ppl at ≥1 matched layer);
   0 layers — the zero-memory-overhead mode — still beats the base method.
7. Fig. 1: acceptance starts ~40% and decays to ~0 as hill climbing
   converges; more calibration sequences → better held-out ppl
   (1 → 8 → 32 seqs: 27.9 → 27.4 → 26.9), the paper's overfitting effect.

{extra_tables}

## §Dry-run

`launch/dryrun.py` lowers + compiles EVERY (arch × shape) cell on the
single-pod (16,16)=256-chip mesh AND the multi-pod (2,16,16)=512-chip mesh
(the "pod" axis crosses DCN). {summary}.

long_500k is skipped by design for the 8 pure-full-attention archs
(DESIGN.md §Arch-applicability). Serving cells (prefill/decode) lower the
QUANTIZED serving path: weights enter as packed 2-bit QTensors (uint32 codes
+ group scales) and are dequantized inside the layer scan — the paper's
technique as a first-class serving feature.

{dryrun}

Notes:
- "collective schedule" lists per-device collective bytes parsed from the
  compiled HLO of the full-depth program (scan bodies appear once; the
  roofline table below uses Δ-extrapolated totals).
- zamba2-7b train_4k peak (32.8 GiB/dev) exceeds v5e HBM: at this batch the
  config needs microbatching (grad accumulation) or zero1 — both implemented
  (`AdamW` grad-accum, `--zero1`); recorded honestly rather than hidden.

## §Roofline

Methodology (launch/roofline.py): XLA counts a `scan` body once, so absolute
per-step costs use the Δ-trick — compile L2/L3-layer variants with every scan
fully unrolled; the difference is the exact per-layer per-device cost;
full-depth = linear extrapolation (exact for layer-linear costs, validated in
`tests/test_roofline.py`). `HLO_bytes` ("bytes accessed") counts per-op
operand+result traffic BEFORE fusion — a conservative upper bound that
systematically over-states HBM traffic (it bills VMEM-resident flash-attention
score tiles as HBM round-trips). It is used as prescribed and consistently,
so relative (before/after) comparisons in §Perf are meaningful; mfu_bound =
(MODEL_FLOPS/chips/peak) / max(term) is therefore a LOWER bound on achievable
MFU.

{roofline}

Reading the table:
- Training cells: memory-term dominated across the board (bytes-accessed
  inflation + full remat); compute term is within 3-12x of the memory term
  for the dense archs (command-r train mfu_bound 0.12 is the best cell).
- useful_ratio (MODEL_FLOPS/HLO_FLOPs) ~0.6-0.8 for dense training (the gap
  = remat recompute + attention quadratic + softmax/norm elementwise);
  ~0.05 for MoE cells (dispatch one-hot/cumsum/scatter machinery — hillclimb
  target #1); ~0.01-0.05 for decode (weights+cache streaming dwarf the
  2·N·1-token useful flops — expected for decode).
- Decode cells: memory-bound as expected for serving; the 2-bit packed
  weights already cut the weight-streaming term ~7x vs bf16 (the paper's
  deployment win, see §Perf cell 3).
- Most collective-bound: mamba2/zamba2 long_500k (seq-sharded KV/state with
  batch=1) — hillclimb target #2.

## §Perf — hypothesis → change → measure log

Strict sequence per assignment: the PAPER-FAITHFUL implementation was built
and validated first (§Paper-claims above = the reproduction baseline); all
optimizations below are the beyond-paper phase, each recorded as
hypothesis → change → before → after → verdict. Baselines for all 40 cells
are in §Roofline; the three hillclimbed cells (selection rationale:
worst roofline fraction / most collective-bound / most representative of the
paper's serving scenario):

{perf}

## §Perf — kernel-level (TPU-target, validated in interpret mode)

- `kernels/quant_matmul.py`: fused 2-bit dequant+matmul. Weight HBM traffic
  per (bk x bn) tile: packed 2-bit + scales = bk*bn/16*4 + (bk/G)*bn*8 bytes
  vs bf16 2*bk*bn -> **6.4x less weight traffic at g128** (kernel_bench.py);
  decode is weight-bound, so the roofline memory term for serving scales
  down by nearly that factor on real hardware.
- `kernels/group_quant.py`: search inner loop; 1 read + 1 write HBM pass vs
  4 passes naive (min/max, qparams, round, dequant) -> 4x traffic reduction
  for the PTQ search itself.
"""


def table1_md():
    p = ROOT / "artifacts" / "benchmarks" / "table1.json"
    if not p.exists():
        return "_run `-m benchmarks.run --only table1` to populate_"
    rows = json.loads(p.read_text())
    out = ["| method | held-out ppl |", "|---|---|"]
    for k, v in rows.items():
        out.append(f"| {k} | {v:.3f} |")
    return "\n".join(out)


def extra_tables_md():
    out = []
    for name, title in (("table2", "Table 2 — transform ablation (held-out ppl)"),
                        ("table3", "Table 3 — bits × group size"),
                        ("table4", "Table 4 — activation-matching depth"),
                        ("fig1", "Figure 1 — calibration-size curves")):
        p = ROOT / "artifacts" / "benchmarks" / f"{name}.json"
        if not p.exists():
            continue
        data = json.loads(p.read_text())
        out.append(f"\n### {title}\n")
        if name == "table2":
            out.append("| variant | ppl |\n|---|---|")
            out += [f"| {k} | {v:.3f} |" for k, v in data.items()]
        elif name == "table3":
            out.append("| setting | bits/param | awq | awq+IE |\n|---|---|---|---|")
            out += [f"| {k} | {v['bits_per_param']:.3f} | {v['awq']:.3f} | "
                    f"{v['awq+invarexplore']:.3f} |" for k, v in data.items()]
        elif name == "table4":
            out.append("| matched layers | ppl | extra MiB |\n|---|---|---|")
            out += [f"| {k} | {v['ppl']:.3f} | {v['extra_MiB']:.2f} |"
                    for k, v in data.items()]
        elif name == "fig1":
            out.append("| calib seqs | final ppl | accept start → end |\n|---|---|---|")
            out += [f"| {k} | {v['final_ppl']:.3f} | "
                    f"{v['initial_accept']:.2f} → {v['final_accept']:.2f} |"
                    for k, v in data.items()]
    return "\n".join(out)


def perf_md():
    p = ROOT / "EXPERIMENTS_PERF.md"
    return p.read_text() if p.exists() else "_(§Perf log pending)_"


def main():
    cells = load_cells()
    md = HEAD.format(
        table1=table1_md(),
        extra_tables=extra_tables_md(),
        summary=summary(cells),
        dryrun=dryrun_table(cells),
        roofline=roofline_table(cells),
        perf=perf_md(),
    )
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(f"EXPERIMENTS.md written ({len(md)} chars)")


if __name__ == "__main__":
    main()
