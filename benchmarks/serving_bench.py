"""Serving-path benchmark: the many-tenant shared-prefix trace, sharing
off vs on.

Runs the SAME deterministic trace (``repro.serving.trace.build_trace`` —
shared system pages + per-tenant template pages + short random tails, with
exact-duplicate requests sprinkled in) through the paged server twice:
prefix cache disabled, then enabled. Asserts the tentpole's acceptance
properties inline — outputs token-identical, >= 50% of prefill tokens
aliased instead of recomputed, allocator fully drained (no page leaked) —
and records them plus p50/p99 TTFT in
``artifacts/benchmarks/BENCH_serving.json`` so CI tracks the sharing win
across commits.
"""
import json
import time

import jax

from benchmarks.common import ART, emit
from repro import obs
from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.serve import PagedServer, Request
from repro.models import init_params
from repro.quantized.qmodel import pack_model
from repro.serving.trace import build_trace

N_TENANTS = 8
PER_TENANT = 3
PAGE_SIZE = 16
MAX_NEW = 8


def _requests(trace):
    return [Request(prompt=t["prompt"], max_new=t["max_new"], seed=t["seed"],
                    tenant=t["tenant"], priority=t["priority"])
            for t in trace]


def _serve(params_q, cfg, trace, *, prefix_cache):
    server = PagedServer(params_q, cfg, max_batch=8, page_size=PAGE_SIZE,
                         n_pages=96, max_len=128, prefix_cache=prefix_cache)
    reqs = _requests(trace)
    t0 = time.time()
    outs = server.generate(reqs)
    wall = time.time() - t0
    alloc = server.cache.allocator
    leaked = alloc.n_pages - alloc.reserved - alloc.num_free
    return server, outs, wall, leaked


def run():
    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    cfg = get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=2, max_seq_len=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    params_q = pack_model(params, QuantConfig(bits=2, group_size=32))

    trace = build_trace(cfg.vocab_size, n_tenants=N_TENANTS,
                        per_tenant=PER_TENANT, page_size=PAGE_SIZE,
                        max_new=MAX_NEW)
    tag = f"trace{N_TENANTS}x{PER_TENANT}"

    off, outs_off, wall_off, leak_off = _serve(params_q, cfg, trace,
                                               prefix_cache=False)
    off_rep = off.sharing_report()   # BEFORE the reset: both servers share
    # the process registry, so the off run's TTFT histogram must be read (and
    # then zeroed) before the on run observes into the same instruments —
    # this is exactly the "registry reset between batcher runs" contract
    # tests/test_obs.py pins
    obs.get_registry().reset()
    on, outs_on, wall_on, leak_on = _serve(params_q, cfg, trace,
                                           prefix_cache=True)

    # the tentpole's acceptance properties, asserted where the numbers are
    # produced so a regressed BENCH_serving.json can never be published
    assert outs_on == outs_off, "prefix sharing changed generated tokens"
    assert leak_off == 0 and leak_on == 0, \
        f"page leak: off={leak_off} on={leak_on}"
    rep = on.sharing_report()
    total = rep["prefill_tokens"] + rep["prefill_tokens_saved"]
    assert off.batcher.stats["prefill_tokens"] == total, \
        "sharing-on trace saw a different token workload than sharing-off"
    assert rep["saved_frac"] >= 0.5, \
        f"prefill_tokens_saved {rep['prefill_tokens_saved']}/{total} < 50%"

    # obs/stats reconciliation: after the reset the registry holds ONLY the
    # sharing-on run, so every counter must equal the batcher's legacy stats
    # dict exactly, and the TTFT histogram must hold one sample per request
    st = on.batcher.stats
    for cname, skey in (("serving_prefill_tokens_total", "prefill_tokens"),
                        ("serving_prefill_tokens_saved_total",
                         "prefill_tokens_saved"),
                        ("serving_aliased_pages_total", "aliased_pages"),
                        ("serving_dedup_admits_total", "dedup_admits"),
                        ("serving_cow_forks_total", "cow_forks"),
                        ("serving_decode_steps_total", "steps")):
        got = obs.counter(cname).total()
        assert got == st[skey], \
            f"obs/stats divergence: {cname}={got} vs stats[{skey!r}]={st[skey]}"
    assert obs.counter("serving_preemptions_total").total() == \
        st["evictions"], "preemption counter drifted from stats['evictions']"
    n_ttft = on.batcher.obs["ttft"].count()
    assert n_ttft == len(trace), \
        f"TTFT histogram holds {n_ttft} samples for {len(trace)} requests"
    assert rep["prefill_tokens_saved"] == \
        obs.counter("serving_prefill_tokens_saved_total").total()

    record(f"serving/prefix_cache/{tag}/off", wall_off * 1e6,
           f"prefill_tokens={off.batcher.stats['prefill_tokens']};"
           f"leaked_pages={leak_off}")
    record(f"serving/prefix_cache/{tag}/on", wall_on * 1e6,
           f"prefill_tokens_saved={rep['prefill_tokens_saved']}"
           f"_of_{total}={rep['saved_frac']:.0%};"
           f"aliased_pages={rep['aliased_pages']};"
           f"dedup_admits={rep['dedup_admits']};"
           f"cow_forks={rep['cow_forks']};"
           f"leaked_pages={leak_on};outputs=token_identical")
    for p in ("p50", "p99"):
        record(f"serving/ttft/{p}", rep[f"ttft_{p}_s"] * 1e6,
               f"sharing_off_{p}_us={off_rep[f'ttft_{p}_s']*1e6:.0f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_serving.json").write_text(json.dumps(rows, indent=1))
    obs.write_snapshot()   # sharing-on run -> artifacts/obs/metrics.json
    return rows


if __name__ == "__main__":
    run()
