"""Kernel microbenchmarks: quant_matmul / group_quant / paged_decode vs
their jnp references.

On this CPU container the Pallas kernels run in interpret mode (slow by
construction); the numbers that matter here are the REFERENCE-path timings
and the analytic HBM-traffic derivation for the TPU target printed as
``derived`` (weight-bytes ratio = the roofline win of the fused kernel; for
paged decode, live-page bytes vs the max_len-capacity cache read).

Rows also land in ``artifacts/benchmarks/BENCH_kernels.json`` so CI can
upload them and a perf trajectory accumulates across commits.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ART, emit, timed
from repro.core.invariance import apply_rotation_cols
from repro.core.quant import QuantConfig, quantize_tensor
from repro.kernels.ref import (group_quant_ref, paged_decode_ref,
                               quant_matmul_ref, transform_quant_ref)


def run():
    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    key = jax.random.PRNGKey(0)
    for (M, K, N, bits, G) in [(8, 2048, 2048, 2, 128), (8, 2048, 2048, 4, 128),
                               (128, 1024, 1024, 2, 64)]:
        w = jax.random.normal(key, (K, N))
        x = jax.random.normal(key, (M, K))
        qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=G))
        f = jax.jit(lambda x, p, s, z: quant_matmul_ref(x, p, s, z, bits, G))
        f(x, qt.packed, qt.scale, qt.zero)[0].block_until_ready()  # warm
        _, us = timed(lambda: jax.block_until_ready(
            f(x, qt.packed, qt.scale, qt.zero)), repeat=5)
        dense_bytes = K * N * 2
        packed_bytes = qt.memory_bytes()
        record(f"kernel/quant_matmul/{M}x{K}x{N}b{bits}", us,
               f"weight_hbm_ratio={dense_bytes/packed_bytes:.2f}x")

    for (K, N, bits, G) in [(2048, 2048, 2, 128), (4096, 1024, 4, 64)]:
        w = jax.random.normal(key, (K, N))
        f = jax.jit(lambda w: group_quant_ref(w, bits, G))
        jax.block_until_ready(f(w))
        _, us = timed(lambda: jax.block_until_ready(f(w)), repeat=5)
        # fused kernel: 1 read + 1 write vs 4 passes un-fused
        record(f"kernel/group_quant/{K}x{N}b{bits}", us, "fused_hbm_passes=2_of_8")

    # fused transform+fake-quant (the population search's per-proposal hot
    # path) vs materialize-then-quantize. ``derived``: the fused kernel reads
    # the weight once and writes the roundtrip once (2 HBM passes) where the
    # unfused path also materializes T(θ) in fp32 and re-reads it to quantize
    # (4 passes) — a 2x weight-traffic cut per proposal on the TPU target.
    # CPU proxy: one composed XLA program vs two jit programs with a real
    # materialization boundary between them.
    for (F, G) in [(256, 64), (512, 128), (512, 32)]:
        D, bits = 256, 2
        w = jax.random.normal(key, (D, F))
        pi = jax.random.permutation(jax.random.PRNGKey(1), F).astype(jnp.int32)
        s = 1.0 + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (F,))
        phi = 1e-3 * jax.random.normal(jax.random.PRNGKey(3), (F // 2,))
        t_stage = jax.jit(lambda w, pi, s, phi:
                          (apply_rotation_cols(w, phi) * s[None, :])[:, pi])
        q_stage = jax.jit(lambda t: group_quant_ref(t, bits, G)[0])
        fused = jax.jit(lambda w, pi, s, phi: transform_quant_ref(
            w, pi, s, phi, bits=bits, group=G, mode="up")[0])
        jax.block_until_ready(q_stage(t_stage(w, pi, s, phi)))  # warm
        jax.block_until_ready(fused(w, pi, s, phi))
        _, us_mat = timed(lambda: jax.block_until_ready(
            q_stage(jax.block_until_ready(t_stage(w, pi, s, phi)))), repeat=5)
        _, us_fused = timed(lambda: jax.block_until_ready(
            fused(w, pi, s, phi)), repeat=5)
        record(f"kernel/transform_quant/F{F}g{G}/materialize", us_mat,
               "weight_hbm_passes=4")
        record(f"kernel/transform_quant/F{F}g{G}/fused", us_fused,
               f"weight_hbm_passes=2_of_4={us_mat/max(us_fused, 1e-9):.2f}x_cpu")

    # paged decode attention: B sequences at ragged depths over a page pool.
    # ``derived``: CAPACITY ratio — tokens a contiguous (B, max_len) cache
    # must hold in HBM vs the page-granular live allocation. Since the
    # dead-page skip (pl.when on page index vs length + clamped block
    # index), the same ratio bounds the kernel's decode READ traffic too:
    # dead block-table slots issue no DMA, so reads track live pages.
    for (B, H, Dh, psz, max_pages, fill) in [(8, 8, 64, 16, 16, 0.5),
                                             (16, 8, 64, 32, 8, 0.25)]:
        n_pages = B * max_pages + 1
        kp = jax.random.normal(key, (n_pages, psz, H, Dh))
        vp = jax.random.normal(key, (n_pages, psz, H, Dh))
        q = jax.random.normal(key, (B, H, Dh))
        bt = jnp.asarray(
            1 + np.arange(B * max_pages).reshape(B, max_pages), jnp.int32)
        lens = jnp.full((B,), int(max_pages * psz * fill), jnp.int32)
        f = jax.jit(lambda q, kp, vp, bt, lens: paged_decode_ref(
            q, kp, vp, bt, lens))
        jax.block_until_ready(f(q, kp, vp, bt, lens))
        _, us = timed(lambda: jax.block_until_ready(f(q, kp, vp, bt, lens)),
                      repeat=5)
        live_pages = B * -(-int(lens[0]) // psz)  # page-granular allocation
        cap_pages = B * max_pages
        record(f"kernel/paged_decode/B{B}xH{H}xD{Dh}p{psz}", us,
               f"capacity_vs_live_pages={cap_pages/max(live_pages, 1):.2f}x")

    # fused-GQA paged decode: the per-query-head grid DMAs each KV head's
    # page ``rep = H // Hkv`` times per decode token; the (B, Hkv, P) fused
    # grid loads it ONCE and batches the group's query heads against it in
    # VMEM. ``derived``: the KV-page HBM read cut (the decode-dominant term).
    # CPU proxy: repeat-KV-to-H-heads oracle vs a grouped einsum that never
    # repeats the pool.
    for (B, H, Hkv, Dh, psz, max_pages) in [(8, 8, 2, 64, 16, 8),
                                            (8, 16, 4, 64, 16, 8)]:
        rep = H // Hkv
        n_pages = B * max_pages + 1
        kp = jax.random.normal(key, (n_pages, psz, Hkv, Dh))
        vp = jax.random.normal(key, (n_pages, psz, Hkv, Dh))
        q = jax.random.normal(key, (B, H, Dh))
        bt = jnp.asarray(
            1 + np.arange(B * max_pages).reshape(B, max_pages), jnp.int32)
        lens = jnp.full((B,), max_pages * psz, jnp.int32)
        unfused = jax.jit(lambda q, kp, vp, bt, lens: paged_decode_ref(
            q, kp, vp, bt, lens))

        def gqa_grouped_ref(q, kp, vp, bt, lens):
            # read each KV head once; queries grouped (B, Hkv, rep, Dh)
            Bq, Hq, D = q.shape
            P, ps = bt.shape[1], kp.shape[1]
            kf = kp[bt].reshape(Bq, P * ps, Hkv, D)
            vf = vp[bt].reshape(Bq, P * ps, Hkv, D)
            qg = q.reshape(Bq, Hkv, Hq // Hkv, D)
            s = jnp.einsum("bgrd,bsgd->bgrs", qg, kf) * D ** -0.5
            mask = jnp.arange(P * ps)[None, :] < lens[:, None]
            s = jnp.where(mask[:, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bgrs,bsgd->bgrd", p, vf).reshape(Bq, Hq, D)

        fused = jax.jit(gqa_grouped_ref)
        jax.block_until_ready(unfused(q, kp, vp, bt, lens))
        jax.block_until_ready(fused(q, kp, vp, bt, lens))
        _, us_u = timed(lambda: jax.block_until_ready(
            unfused(q, kp, vp, bt, lens)), repeat=5)
        _, us_f = timed(lambda: jax.block_until_ready(
            fused(q, kp, vp, bt, lens)), repeat=5)
        record(f"kernel/paged_decode_gqa/H{H}kv{Hkv}/unfused", us_u,
               f"kv_page_reads_per_token={H}")
        record(f"kernel/paged_decode_gqa/H{H}kv{Hkv}/fused", us_f,
               f"kv_page_reads_per_token={Hkv}_of_{H}={rep}x_cut="
               f"{us_u / max(us_f, 1e-9):.2f}x_cpu")

        # multi-page inner grid axis (pages_per_block=MP): the fused kernel's
        # per-page (rep, psz) matmul is below MXU granularity for small rep;
        # staging MP pages per online-softmax update replaces MP tiny matmuls
        # with one (rep, MP*psz) one. CPU proxy: an online-softmax scan over
        # single pages vs over MP-page blocks — same math, matmul granularity
        # is the only variable.
        def make_blocked(mp):
            nblk = max_pages // mp

            def f(q, kp, vp, bt, lens):
                Bq, Hq, D = q.shape
                ps = kp.shape[1]
                kf = kp[bt].reshape(Bq, nblk, mp * ps, Hkv, D)
                vf = vp[bt].reshape(Bq, nblk, mp * ps, Hkv, D)
                qg = q.reshape(Bq, Hkv, Hq // Hkv, D)

                def body(carry, xs):
                    o, m, l, blk = carry
                    kb, vb = xs                       # (B, mp*ps, Hkv, D)
                    s = jnp.einsum("bgrd,bsgd->bgrs", qg, kb) * D ** -0.5
                    pos = blk * (mp * ps) + jnp.arange(mp * ps)
                    msk = pos[None, :] < lens[:, None]
                    s = jnp.where(msk[:, None, None], s, -1e30)
                    m2 = jnp.maximum(m, s.max(-1))
                    prob = jnp.where(msk[:, None, None],
                                     jnp.exp(s - m2[..., None]), 0.0)
                    corr = jnp.exp(m - m2)
                    o = o * corr[..., None] + jnp.einsum("bgrs,bsgd->bgrd",
                                                         prob, vb)
                    return (o, m2, l * corr + prob.sum(-1), blk + 1), None

                init = (jnp.zeros((Bq, Hkv, Hq // Hkv, D)),
                        jnp.full((Bq, Hkv, Hq // Hkv), -1e30),
                        jnp.zeros((Bq, Hkv, Hq // Hkv)), jnp.int32(0))
                (o, m, l, _), _ = jax.lax.scan(
                    body, init, (kf.swapaxes(0, 1), vf.swapaxes(0, 1)))
                return (o / jnp.maximum(l, 1e-30)[..., None]
                        ).reshape(Bq, Hq, D)
            return jax.jit(f)

        one = make_blocked(1)
        blk4 = make_blocked(4)
        np.testing.assert_allclose(
            np.asarray(one(q, kp, vp, bt, lens)),
            np.asarray(blk4(q, kp, vp, bt, lens)), atol=1e-5)
        _, us_1 = timed(lambda: jax.block_until_ready(
            one(q, kp, vp, bt, lens)), repeat=5)
        _, us_4 = timed(lambda: jax.block_until_ready(
            blk4(q, kp, vp, bt, lens)), repeat=5)
        record(f"kernel/paged_decode_gqa/H{H}kv{Hkv}/fused_mp1", us_1,
               f"matmul_shape={rep}x{psz}_per_update")
        record(f"kernel/paged_decode_gqa/H{H}kv{Hkv}/fused_mp4", us_4,
               f"matmul_shape={rep}x{4 * psz}_per_update="
               f"{us_1 / max(us_4, 1e-9):.2f}x_cpu")

    # chunked paged prefill: prompt K/V written straight into pages, chunk
    # attention streamed page-by-page from the pool. ``derived``: admit
    # tokens/sec through the attention path plus the copy the v1 admit no
    # longer pays (contiguous prefill + write_prefill scatter re-touched
    # every prompt KV byte once more).
    from repro.serving.prefill import paged_prefill_attention
    for (plen, psz, H, Dh, chunk_pages) in [(256, 16, 8, 64, 4),
                                            (512, 32, 8, 64, 4)]:
        n_pages = plen // psz + 1
        pools = {"k": jax.random.normal(key, (n_pages, psz, H, Dh)),
                 "v": jax.random.normal(key, (n_pages, psz, H, Dh))}
        bt = jnp.asarray(1 + np.arange(plen // psz), jnp.int32)[None]
        C = chunk_pages * psz
        f = jax.jit(lambda q, pools, bt, off: paged_prefill_attention(
            q, pools, bt, off))
        q = jax.random.normal(key, (1, C, H, Dh))
        jax.block_until_ready(f(q, pools, bt, jnp.int32(0)))
        def run_chunks():
            for off in range(0, plen, C):
                jax.block_until_ready(f(q, pools, bt, jnp.int32(off)))
        _, us = timed(run_chunks, repeat=3)
        toks_per_s = plen / (us * 1e-6)
        kv_bytes = 2 * plen * H * Dh * 4
        record(f"kernel/paged_prefill/S{plen}p{psz}c{chunk_pages}", us,
               f"prefill_toks_per_s={toks_per_s:.0f};"
               f"admit_copy_bytes_saved={kv_bytes}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_kernels.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    run()
