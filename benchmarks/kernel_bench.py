"""Kernel microbenchmarks: quant_matmul / group_quant vs their jnp references.

On this CPU container the Pallas kernels run in interpret mode (slow by
construction); the numbers that matter here are the REFERENCE-path timings
and the analytic HBM-traffic derivation for the TPU target printed as
``derived`` (weight-bytes ratio = the roofline win of the fused kernel).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.quant import QuantConfig, quantize_tensor
from repro.kernels.ref import quant_matmul_ref, group_quant_ref


def run():
    key = jax.random.PRNGKey(0)
    for (M, K, N, bits, G) in [(8, 2048, 2048, 2, 128), (8, 2048, 2048, 4, 128),
                               (128, 1024, 1024, 2, 64)]:
        w = jax.random.normal(key, (K, N))
        x = jax.random.normal(key, (M, K))
        qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=G))
        f = jax.jit(lambda x, p, s, z: quant_matmul_ref(x, p, s, z, bits, G))
        f(x, qt.packed, qt.scale, qt.zero)[0].block_until_ready()  # warm
        _, us = timed(lambda: jax.block_until_ready(
            f(x, qt.packed, qt.scale, qt.zero)), repeat=5)
        dense_bytes = K * N * 2
        packed_bytes = qt.memory_bytes()
        emit(f"kernel/quant_matmul/{M}x{K}x{N}b{bits}", us,
             f"weight_hbm_ratio={dense_bytes/packed_bytes:.2f}x")

    for (K, N, bits, G) in [(2048, 2048, 2, 128), (4096, 1024, 4, 64)]:
        w = jax.random.normal(key, (K, N))
        f = jax.jit(lambda w: group_quant_ref(w, bits, G))
        jax.block_until_ready(f(w))
        _, us = timed(lambda: jax.block_until_ready(f(w)), repeat=5)
        # fused kernel: 1 read + 1 write vs 4 passes un-fused
        emit(f"kernel/group_quant/{K}x{N}b{bits}", us, "fused_hbm_passes=2_of_8")


if __name__ == "__main__":
    run()
