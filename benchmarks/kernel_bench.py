"""Kernel microbenchmarks: quant_matmul / group_quant / paged_decode vs
their jnp references.

On this CPU container the Pallas kernels run in interpret mode (slow by
construction); the numbers that matter here are the REFERENCE-path timings
and the analytic HBM-traffic derivation for the TPU target printed as
``derived`` (weight-bytes ratio = the roofline win of the fused kernel; for
paged decode, live-page bytes vs the max_len-capacity cache read).

Rows also land in ``artifacts/benchmarks/BENCH_kernels.json`` so CI can
upload them and a perf trajectory accumulates across commits.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ART, emit, timed
from repro.core.quant import QuantConfig, quantize_tensor
from repro.kernels.ref import (group_quant_ref, paged_decode_ref,
                               quant_matmul_ref)


def run():
    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    key = jax.random.PRNGKey(0)
    for (M, K, N, bits, G) in [(8, 2048, 2048, 2, 128), (8, 2048, 2048, 4, 128),
                               (128, 1024, 1024, 2, 64)]:
        w = jax.random.normal(key, (K, N))
        x = jax.random.normal(key, (M, K))
        qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=G))
        f = jax.jit(lambda x, p, s, z: quant_matmul_ref(x, p, s, z, bits, G))
        f(x, qt.packed, qt.scale, qt.zero)[0].block_until_ready()  # warm
        _, us = timed(lambda: jax.block_until_ready(
            f(x, qt.packed, qt.scale, qt.zero)), repeat=5)
        dense_bytes = K * N * 2
        packed_bytes = qt.memory_bytes()
        record(f"kernel/quant_matmul/{M}x{K}x{N}b{bits}", us,
               f"weight_hbm_ratio={dense_bytes/packed_bytes:.2f}x")

    for (K, N, bits, G) in [(2048, 2048, 2, 128), (4096, 1024, 4, 64)]:
        w = jax.random.normal(key, (K, N))
        f = jax.jit(lambda w: group_quant_ref(w, bits, G))
        jax.block_until_ready(f(w))
        _, us = timed(lambda: jax.block_until_ready(f(w)), repeat=5)
        # fused kernel: 1 read + 1 write vs 4 passes un-fused
        record(f"kernel/group_quant/{K}x{N}b{bits}", us, "fused_hbm_passes=2_of_8")

    # paged decode attention: B sequences at ragged depths over a page pool.
    # ``derived``: CAPACITY ratio — tokens a contiguous (B, max_len) cache
    # must hold in HBM vs the page-granular live allocation. This is the
    # paging memory win (more sequences per pool), NOT streamed decode
    # bytes: the shipped kernel still visits every block-table slot
    # (masked-page skipping is a ROADMAP item), so read traffic is
    # capacity-bound either way.
    for (B, H, Dh, psz, max_pages, fill) in [(8, 8, 64, 16, 16, 0.5),
                                             (16, 8, 64, 32, 8, 0.25)]:
        n_pages = B * max_pages + 1
        kp = jax.random.normal(key, (n_pages, psz, H, Dh))
        vp = jax.random.normal(key, (n_pages, psz, H, Dh))
        q = jax.random.normal(key, (B, H, Dh))
        bt = jnp.asarray(
            1 + np.arange(B * max_pages).reshape(B, max_pages), jnp.int32)
        lens = jnp.full((B,), int(max_pages * psz * fill), jnp.int32)
        f = jax.jit(lambda q, kp, vp, bt, lens: paged_decode_ref(
            q, kp, vp, bt, lens))
        jax.block_until_ready(f(q, kp, vp, bt, lens))
        _, us = timed(lambda: jax.block_until_ready(f(q, kp, vp, bt, lens)),
                      repeat=5)
        live_pages = B * -(-int(lens[0]) // psz)  # page-granular allocation
        cap_pages = B * max_pages
        record(f"kernel/paged_decode/B{B}xH{H}xD{Dh}p{psz}", us,
               f"capacity_vs_live_pages={cap_pages/max(live_pages, 1):.2f}x")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "BENCH_kernels.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    run()
