"""Paper Table 4: activation-matching depth (0/1/2/4 layers) + the memory
overhead of storing H0.

Claim replicated: more matched layers generally help; 0 layers (no memory
overhead) still beats the base method.
"""
import json

import numpy as np

from benchmarks.common import ART, bench_model, calib_set, heldout_set, ppl, emit, timed
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig


def run(search_steps: int = 300):
    params, cfg = bench_model()
    calib = calib_set(cfg)
    held = heldout_set(cfg)
    qcfg = QuantConfig(bits=2, group_size=32)

    rows = {}
    n_tok = int(np.prod(calib.shape))
    for n_match in (0, 1, 2, 4):
        scfg = SearchConfig(steps=search_steps, n_match_layers=n_match, log_every=0)
        r, us = timed(lambda: quantize_model(params, cfg, qcfg, method="awq",
                                             calib_tokens=calib, search=scfg))
        # H0 memory: n_match layers x calib tokens x d_model x 4B
        mem = n_match * n_tok * cfg.d_model * 4
        rows[f"{n_match}_layers"] = {"ppl": ppl(r.params_q, cfg, held),
                                     "extra_MiB": mem / 2**20}
        emit(f"table4/match{n_match}", us,
             f"ppl={rows[f'{n_match}_layers']['ppl']:.3f};MiB={mem/2**20:.2f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table4.json").write_text(json.dumps(rows, indent=1))
    print("\nTable 4 (activation-matching depth):")
    for k, v in rows.items():
        print(f"  {k:10s} ppl={v['ppl']:9.3f} extra={v['extra_MiB']:6.2f} MiB")
    return rows


if __name__ == "__main__":
    run()
