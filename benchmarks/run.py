"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows (assignment format) and writes
each table's JSON to artifacts/benchmarks/. See DESIGN.md §7 for the
paper-table ↔ benchmark mapping.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer search steps (CI-speed run)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,table3,table4,"
                         "fig1,kernels,serving,search")
    args = ap.parse_args()
    steps = 120 if args.fast else 400

    from benchmarks import (table1_main, table2_ablation, table3_bits,
                            table4_actmatch, fig1_curves, kernel_bench,
                            serving_bench)

    def search_mem_bench():
        # K=8 candidate eval, O(unit) dynamic-slice install vs K full stacks:
        # search_unit_install/ and search_stack_install/ rows with
        # peak_live_bytes (jax.live_arrays() delta) in BENCH_search.json
        from repro.launch.search import run_search_bench
        for mode in ("unit", "stack"):
            run_search_bench(steps=4 if args.fast else 16, population=8,
                             n_seqs=2, seq_len=64, install=mode,
                             measure_mem=True)

    jobs = {
        "table1": lambda: table1_main.run(search_steps=steps),
        "table2": lambda: table2_ablation.run(search_steps=max(steps * 3 // 4, 80)),
        "table3": lambda: table3_bits.run(search_steps=max(steps * 5 // 8, 80)),
        "table4": lambda: table4_actmatch.run(search_steps=max(steps * 3 // 4, 80)),
        "fig1": lambda: fig1_curves.run(search_steps=steps),
        "kernels": kernel_bench.run,
        "serving": serving_bench.run,
        "search": search_mem_bench,
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    t0 = time.time()
    print("name,us_per_call,derived")
    for name, fn in jobs.items():
        if name not in only:
            continue
        t1 = time.time()
        fn()
        print(f"# {name} done in {time.time()-t1:.1f}s", file=sys.stderr)
    print(f"# all benchmarks in {time.time()-t0:.1f}s", file=sys.stderr)
    # attach the final merged metrics snapshot next to the bench tables (the
    # serving bench resets the registry mid-run; this captures what remains
    # after the last job plus whatever earlier jobs already merged into it)
    from repro import obs
    p = obs.write_snapshot()
    print(f"# metrics snapshot -> {p}", file=sys.stderr)


if __name__ == "__main__":
    main()
