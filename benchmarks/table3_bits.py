"""Paper Table 3: bits × group-size sweep (1/2/3-bit, g=16/32) for AWQ ±
InvarExplore, with the effective bits/param accounting.

Claims replicated: 1-bit collapses (IE reduces ppl by a lot but can't rescue
it), 2-bit benefits most from IE, 3-bit is near-saturated; smaller groups
help at a small memory cost.
"""
import json

from benchmarks.common import ART, bench_model, calib_set, heldout_set, ppl, emit, timed
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig, bits_per_param
from repro.core.search import SearchConfig

SETTINGS = [(1, 16), (2, 16), (2, 32), (3, 32)]


def run(search_steps: int = 250):
    params, cfg = bench_model()
    calib = calib_set(cfg)
    held = heldout_set(cfg)

    rows = {}
    for bits, group in SETTINGS:
        qcfg = QuantConfig(bits=bits, group_size=group)
        bpp = bits_per_param(qcfg, scale_bits=16, zero_bits=0)
        r, us = timed(lambda: quantize_model(params, cfg, qcfg, method="awq",
                                             calib_tokens=calib))
        base = ppl(r.params_q, cfg, held)
        scfg = SearchConfig(steps=search_steps, n_match_layers=4, log_every=0)
        r2, us2 = timed(lambda: quantize_model(params, cfg, qcfg, method="awq",
                                               calib_tokens=calib, search=scfg))
        ie = ppl(r2.params_q, cfg, held)
        key = f"{bits}bit-g{group}"
        rows[key] = {"bits_per_param": bpp, "awq": base, "awq+invarexplore": ie}
        emit(f"table3/{key}/awq", us, f"ppl={base:.3f};bpp={bpp:.3f}")
        emit(f"table3/{key}/awq+ie", us2, f"ppl={ie:.3f};bpp={bpp:.3f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table3.json").write_text(json.dumps(rows, indent=1))
    print("\nTable 3 (bits x group):")
    for k, v in rows.items():
        print(f"  {k:10s} bpp={v['bits_per_param']:.3f} "
              f"awq={v['awq']:9.3f} +IE={v['awq+invarexplore']:9.3f}")
    assert rows["1bit-g16"]["awq"] > rows["2bit-g16"]["awq"], "1-bit must be worst"
    assert rows["2bit-g16"]["awq"] <= rows["2bit-g32"]["awq"] * 1.10, \
        "finer groups should not be much worse"
    return rows


if __name__ == "__main__":
    run()
