"""Paper Table 2: transform ablation (Permutation / Scaling / Rotation / All)
on top of AWQ.

Claim replicated: each transform alone improves over AWQ; combining all three
is the best (synergy).
"""
import json

from benchmarks.common import ART, bench_model, calib_set, heldout_set, ppl, emit, timed
from repro.core.invariance import ProposalConfig
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig

VARIANTS = {
    "awq": None,
    "+IE-permutation": ProposalConfig(use_scaling=False, use_rotation=False),
    "+IE-scaling": ProposalConfig(use_permutation=False, use_rotation=False),
    "+IE-rotation": ProposalConfig(use_permutation=False, use_scaling=False),
    "+IE-all": ProposalConfig(),
}


def run(search_steps: int = 300):
    params, cfg = bench_model()
    calib = calib_set(cfg)
    held = heldout_set(cfg)
    qcfg = QuantConfig(bits=2, group_size=32)

    rows = {}
    for name, pcfg in VARIANTS.items():
        scfg = None if pcfg is None else SearchConfig(
            steps=search_steps, n_match_layers=4, log_every=0, proposal=pcfg)
        r, us = timed(lambda: quantize_model(params, cfg, qcfg, method="awq",
                                             calib_tokens=calib, search=scfg))
        rows[name] = ppl(r.params_q, cfg, held)
        emit(f"table2/{name}", us, f"ppl={rows[name]:.3f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table2.json").write_text(json.dumps(rows, indent=1))
    print("\nTable 2 (transform ablation, held-out ppl):")
    for k, v in rows.items():
        print(f"  {k:18s} {v:10.3f}")
    assert rows["+IE-all"] <= min(rows.values()) * 1.05, "combined should be ~best"
    return rows


if __name__ == "__main__":
    run()
