"""Shared benchmark scaffolding: one trained OPT-family model (cached on
disk), calibration + held-out evaluation sets, ppl helpers, CSV output.

Scale note (DESIGN.md §7): the paper evaluates OPT-1.3B..13B on WikiText-2;
this CPU container trains an OPT-architecture model (ReLU/LayerNorm/learned
positions — where the paper's scaling invariance is exact) on a deterministic
synthetic corpus. Every table reproduces the paper's QUALITATIVE claims; the
full-size configs are exercised structurally by the dry-run.
"""
from __future__ import annotations

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.objective import calib_ce
from repro.data.calib import calibration_tokens
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import forward

CKPT = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "bench_model"
ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "benchmarks"

BENCH_CFG = get_config("opt-tiny").reduced(
    n_layers=4, d_model=96, d_ff=256, vocab_size=384, n_heads=4, n_kv_heads=4,
    max_seq_len=256)


def bench_model(steps: int = 400):
    """Train (or load) the shared benchmark model."""
    from repro.ckpt.checkpoint import save_checkpoint, restore_checkpoint, latest_step
    cfg = BENCH_CFG
    if latest_step(CKPT) is not None:
        params, _ = restore_checkpoint(CKPT)
        return params, cfg
    from repro.launch.train import train
    params, losses, _ = train(steps=steps, batch=16, seq=128, lr=1.5e-3,
                              cfg=cfg, log_every=100)
    save_checkpoint(CKPT, steps, params)
    return params, cfg


def calib_set(cfg, n_seqs=32, seq_len=128):
    """Paper §4.1: 32 sequences (512 tokens there; 128 here — same ratio of
    calib tokens to model capacity)."""
    return jnp.asarray(calibration_tokens(cfg.vocab_size, n_seqs=n_seqs,
                                          seq_len=seq_len))


def heldout_set(cfg, n_seqs=16, seq_len=128, seed=4242):
    batch_at = make_pipeline(DataConfig(seq_len=seq_len, global_batch=n_seqs,
                                        seed=seed, vocab_size=cfg.vocab_size))
    return jnp.asarray(batch_at(0))


def ppl(params, cfg, tokens) -> float:
    """Held-out perplexity (the paper's WikiText-2/C4 metric)."""
    return float(jnp.exp(calib_ce(forward(params, cfg, tokens), tokens,
                                  cfg.vocab_size)))


def emit(name: str, us_per_call: float, derived: str):
    """Assignment-required CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeat=1):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6
