"""Paper Table 1: RTN / GPTQ / AWQ / OmniQuant ± InvarExplore, 2-bit g128.

Claims replicated: (i) 2-bit RTN is catastrophic, (ii) calibrated methods
recover most of it, (iii) +InvarExplore is an ADD-ON improvement over every
base method.
"""
import json


from benchmarks.common import (ART, bench_model, calib_set, heldout_set, ppl,
                               emit, timed)
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig


def run(search_steps: int = 400, bits: int = 2, group: int = 32):
    params, cfg = bench_model()
    calib = calib_set(cfg)
    held = heldout_set(cfg)
    qcfg = QuantConfig(bits=bits, group_size=group)
    scfg = SearchConfig(steps=search_steps, n_match_layers=4, log_every=0)

    rows = {"fp32": ppl(params, cfg, held)}
    for method in ("rtn", "gptq", "awq", "omniquant"):
        r, us = timed(lambda: quantize_model(params, cfg, qcfg, method=method,
                                             calib_tokens=calib))
        rows[method] = ppl(r.params_q, cfg, held)
        emit(f"table1/{method}", us, f"ppl={rows[method]:.3f}")
        r2, us2 = timed(lambda: quantize_model(params, cfg, qcfg, method=method,
                                               calib_tokens=calib, search=scfg))
        rows[method + "+invarexplore"] = ppl(r2.params_q, cfg, held)
        emit(f"table1/{method}+invarexplore", us2,
             f"ppl={rows[method + '+invarexplore']:.3f};accept={r2.search.accept_rate:.2f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "table1.json").write_text(json.dumps(rows, indent=1))
    print("\nTable 1 (held-out ppl, lower=better):")
    for k, v in rows.items():
        print(f"  {k:22s} {v:10.3f}")
    # paper-claim checks
    assert rows["rtn"] > rows["fp32"] * 1.05
    for m in ("gptq", "awq", "omniquant"):
        assert rows[m + "+invarexplore"] <= rows[m] * 1.02, f"{m}: IE regressed"
    assert rows["rtn+invarexplore"] < rows["rtn"]
    return rows


if __name__ == "__main__":
    run()
