"""Paper Figure 1: optimization curves vs number of calibration sequences —
(a) calibration loss, (b) held-out ppl, (c) acceptance rate over steps.

Claims replicated: loss decreases over steps; fewer calibration sequences
over-fit faster (lower calib loss, worse test ppl); acceptance rate starts
high and decays as the search converges.
"""
import json

from benchmarks.common import ART, bench_model, calib_set, heldout_set, ppl, emit, timed
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig


def run(search_steps: int = 400):
    params, cfg = bench_model()
    held = heldout_set(cfg)
    qcfg = QuantConfig(bits=2, group_size=32)

    curves = {}
    for n_seqs in (1, 8, 32):
        calib = calib_set(cfg, n_seqs=n_seqs)
        scfg = SearchConfig(steps=search_steps, n_match_layers=4, log_every=0)
        r, us = timed(lambda: quantize_model(params, cfg, qcfg, method="awq",
                                             calib_tokens=calib, search=scfg))
        hist = r.search.history
        # windowed acceptance rate
        window = max(search_steps // 10, 1)
        acc_curve = []
        for i in range(window, len(hist), window):
            acc = sum(1 for h in hist[i - window:i] if h[4]) / window
            acc_curve.append((i, acc))
        best_curve = []
        best = float("inf")
        for (step, loss, _, _, accepted) in hist:
            if accepted:
                best = min(best, loss)
            if step % window == 0:
                best_curve.append((step, best if best < float("inf") else loss))
        curves[str(n_seqs)] = {
            "calib_loss": best_curve,
            "final_ppl": ppl(r.params_q, cfg, held),
            "acceptance": acc_curve,
            "initial_accept": acc_curve[0][1] if acc_curve else None,
            "final_accept": acc_curve[-1][1] if acc_curve else None,
        }
        emit(f"fig1/nseq{n_seqs}", us,
             f"ppl={curves[str(n_seqs)]['final_ppl']:.3f};"
             f"acc0={curves[str(n_seqs)]['initial_accept']:.2f};"
             f"accT={curves[str(n_seqs)]['final_accept']:.2f}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig1.json").write_text(json.dumps(curves, indent=1))
    print("\nFigure 1 (curves saved to artifacts/benchmarks/fig1.json):")
    for k, v in curves.items():
        print(f"  n_seqs={k:3s} final_ppl={v['final_ppl']:9.3f} "
              f"accept {v['initial_accept']:.2f} -> {v['final_accept']:.2f}")
    for k, v in curves.items():
        assert v["initial_accept"] >= v["final_accept"] - 0.05, \
            "acceptance rate should decay as the search converges"
    return curves


if __name__ == "__main__":
    run()
