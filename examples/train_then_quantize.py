"""End-to-end: TRAIN a small LM on the synthetic corpus (with checkpointing
and fault tolerance), then post-training-quantize it and compare RTN vs
RTN+InvarExplore held-out perplexity.

    PYTHONPATH=src python examples/train_then_quantize.py [--steps 300]
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro.core import QuantConfig
from repro.core.objective import calib_ce
from repro.core.pipeline import quantize_model
from repro.core.search import SearchConfig
from repro.data.calib import calibration_tokens
from repro.data.pipeline import DataConfig, make_pipeline
from repro.launch.train import train
from repro.models import forward


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--search-steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, losses, cfg = train(arch="opt-tiny", steps=args.steps, batch=16,
                                    seq=128, lr=1.5e-3, ckpt_dir=ckpt_dir,
                                    save_every=100)
    print(f"\ntraining: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    calib = jnp.asarray(calibration_tokens(cfg.vocab_size, n_seqs=8, seq_len=128))
    held = jnp.asarray(make_pipeline(DataConfig(seq_len=128, global_batch=8,
                                                seed=777, vocab_size=cfg.vocab_size))(0))

    def ppl(p):
        return float(jnp.exp(calib_ce(forward(p, cfg, held), held, cfg.vocab_size)))

    qcfg = QuantConfig(bits=2, group_size=32)
    r_rtn = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib)
    r_ie = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib,
                          search=SearchConfig(steps=args.search_steps,
                                              n_match_layers=2, log_every=100))
    print(f"\nheld-out ppl:  fp32={ppl(params):8.2f}")
    print(f"               rtn ={ppl(r_rtn.params_q):8.2f}")
    print(f"               +IE ={ppl(r_ie.params_q):8.2f}   "
          f"(accept {r_ie.search.accept_rate:.1%})")


if __name__ == "__main__":
    main()
