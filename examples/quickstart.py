"""Quickstart: quantize a model to 2 bits with InvarExplore in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import QuantConfig
from repro.core.pipeline import quantize_model
from repro.core.search import SearchConfig
from repro.core.objective import calib_ce
from repro.data.calib import calibration_tokens
from repro.models import init_params, forward

# 1. a model (here: random-init tiny OPT; swap in your own params pytree)
cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                     vocab_size=256, n_heads=4, n_kv_heads=4)
params = init_params(jax.random.PRNGKey(0), cfg)

# 2. a small calibration set (paper: 32 x 512 tokens from the Pile)
calib = jnp.asarray(calibration_tokens(cfg.vocab_size, n_seqs=4, seq_len=128))

# 3. ultra-low-bit PTQ: AWQ base + InvarExplore discrete search on top
qcfg = QuantConfig(bits=2, group_size=32)
result = quantize_model(
    params, cfg, qcfg,
    method="awq",                                   # rtn | gptq | awq | omniquant
    calib_tokens=calib,
    search=SearchConfig(steps=150, n_match_layers=2, log_every=50),
)

ce_fp = float(calib_ce(forward(params, cfg, calib), calib, cfg.vocab_size))
ce_q = float(calib_ce(forward(result.params_q, cfg, calib), calib, cfg.vocab_size))
print(f"\nmethod={result.method}")
print(f"calib CE: fp32={ce_fp:.4f}  2-bit={ce_q:.4f}")
print(f"search: {result.search.initial_loss:.4f} -> {result.search.final_loss:.4f} "
      f"(accept rate {result.search.accept_rate:.1%})")
