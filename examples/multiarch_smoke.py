"""Run a forward + train step on EVERY assigned architecture (reduced config)
and apply the family-appropriate InvarExplore adapter to each — demonstrates
the technique as a first-class feature across dense / MoE / SSM families.

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import get_config, list_archs
from repro.core.quant import QuantConfig
from repro.core.search import make_adapter
from repro.models import init_params, forward, lm_loss
from repro.models.frontends import stub_vision_embeds, stub_audio_frames

qcfg = QuantConfig(bits=2, group_size=32)
key = jax.random.PRNGKey(0)

for arch in list_archs():
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    kw = {}
    if cfg.frontend == "vision":
        kw["vision_embeds"] = stub_vision_embeds(key, cfg, 2, 8)
    if cfg.is_enc_dec:
        kw["enc_embeds"] = stub_audio_frames(key, cfg, 2, 16)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    loss = lm_loss(forward(params, cfg, tokens, **kw)[:, -32:], tokens, cfg.vocab_size)

    adapter = make_adapter(cfg)
    note = f"adapter={type(adapter).__name__} units={adapter.n_units}"
    if cfg.block_pattern == "hybrid":
        shared = make_adapter(cfg, phase="shared")
        note += f" + {type(shared).__name__} (two-phase)"
    print(f"{arch:24s} loss={float(loss):.3f}  {note}")
print("\nall architectures OK")
