"""End-to-end serving driver (the paper's deployment scenario): pack a model
to 2-bit QTensors and serve a MIXED-LENGTH request stream through the paged
KV cache + continuous batcher, reporting the memory split and tokens/s.

    PYTHONPATH=src python examples/serve_quantized.py --requests 8
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.serve import PagedServer, Request
from repro.models import init_params
from repro.quantized.qmodel import (pack_model, packed_bytes, dense_bytes,
                                    serving_memory_report)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(n_layers=4, d_model=128, d_ff=512,
                                        vocab_size=512, n_heads=4, n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qcfg = QuantConfig(bits=args.bits, group_size=args.group)
    params_q = pack_model(params, qcfg)
    pb, db = packed_bytes(params_q), dense_bytes(params_q)
    print(f"[serve] weights: packed={pb/1e6:.2f} MB vs fp16-dense={db/1e6:.2f} MB "
          f"on quantized leaves ({db/pb:.1f}x)")

    server = PagedServer(params_q, cfg, max_batch=args.batch,
                         page_size=args.page_size, max_len=args.max_len)
    rep = serving_memory_report(params_q, server.cache.pools)
    print(f"[serve] page pool {server.cache.n_pages} x {args.page_size} tokens; "
          f"kv_fraction={rep['kv_fraction']:.2f} of serving memory")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 16))).astype(np.int32),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    outs = server.generate(reqs)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests -> {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    print(f"[serve] batcher stats: {server.batcher.stats}")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: prompt_len={len(reqs[i].prompt)} -> {o[:8]}...")
    return outs


if __name__ == "__main__":
    main()
