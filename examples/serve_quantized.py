"""End-to-end serving driver (the paper's deployment scenario): pack a model
to 2-bit QTensors and serve BATCHED requests through prefill + greedy decode,
reporting the memory saving and tokens/s.

    PYTHONPATH=src python examples/serve_quantized.py --requests 8
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.serve import BatchedServer, Request
from repro.models import init_params
from repro.quantized.qmodel import pack_model, packed_bytes, dense_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=4, d_model=128, d_ff=512,
                                        vocab_size=512, n_heads=4, n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qcfg = QuantConfig(bits=args.bits, group_size=args.group)
    params_q = pack_model(params, qcfg)
    pb, db = packed_bytes(params_q), dense_bytes(params_q)
    print(f"[serve] weights: packed={pb/1e6:.2f} MB vs fp16-dense={db/1e6:.2f} MB "
          f"on quantized leaves ({db/pb:.1f}x)")

    server = BatchedServer(params_q, cfg, batch_size=args.batch, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 16))).astype(np.int32),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    outs = server.generate(reqs)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests -> {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: prompt_len={len(reqs[i].prompt)} -> {o[:8]}...")


if __name__ == "__main__":
    main()
