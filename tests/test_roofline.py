"""Roofline analyzer logic: HLO collective parsing + extrapolation math."""
import pytest

from repro.launch.roofline import (collective_bytes, extrapolate,
                                   roofline_terms, _type_bytes)

HLO_SAMPLE = """
HloModule jit_step
%fused (p: bf16[128,256]) -> bf16[128,256] { ... }
%ar = bf16[2048,8192]{1,0} all-reduce(bf16[2048,8192]{1,0} %x), replica_groups={...}
%ag = f32[512,1024]{1,0} all-gather(f32[32,1024]{1,0} %y), dimensions={0}
%rs = f32[64,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %z), dimensions={0}
%cp = bf16[16,16]{1,0} collective-permute(bf16[16,16]{1,0} %w)
%ars = bf16[4,4]{1,0} all-reduce-start(bf16[4,4]{1,0} %v)
%ard = bf16[4,4]{1,0} all-reduce-done(bf16[4,4]{1,0} %ars)
%a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %p, f32[8,8]{1,0} %q)
"""


def test_type_bytes():
    assert _type_bytes("bf16[2048,8192]{1,0}") == 2048 * 8192 * 2
    assert _type_bytes("f32[512,1024]") == 512 * 1024 * 4
    assert _type_bytes("(f32[8,8], bf16[4])") == 8 * 8 * 4 + 4 * 2


def test_collective_bytes_parsing():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-reduce"] == 2048 * 8192 * 2 + 4 * 4 * 2  # incl. -start, not -done
    assert got["all-gather"] == 512 * 1024 * 4
    assert got["reduce-scatter"] == 1024 * 128 * 4            # max(result, operand)
    assert got["collective-permute"] == 16 * 16 * 2
    assert got["all-to-all"] == 2 * 8 * 8 * 4


def test_extrapolation_exact_for_linear():
    # cost(L) = 7 + 3L  ->  extrapolating from L=2,3 to 24 must be exact
    def f(L):
        return 7 + 3 * L
    assert extrapolate(f(2), f(3), 2, 3, 24) == pytest.approx(f(24))


def test_roofline_terms_dominance():
    t = roofline_terms(flops_dev=197e12, bytes_dev=819e9 * 2, coll_dev=50e9 * 0.5)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] == "memory_s"
    assert t["overlap_fraction"] == pytest.approx(2.0 / 3.5)


def test_model_flops_conventions():
    from repro.launch.roofline import model_flops
    from repro.configs import get_config
    cfg = get_config("yi-6b")
    n = cfg.active_param_count()
    assert model_flops(cfg, 1000, train=True) == pytest.approx(6.0 * n * 1000)
    assert model_flops(cfg, 1000, train=False) == pytest.approx(2.0 * n * 1000)
