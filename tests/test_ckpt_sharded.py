"""Sharded (format-2) checkpointing: shard-manifest property tests, elastic
re-mesh restore, corruption detection, and the CheckpointManager
checksum-verification regression (ISSUE 5).

The multi-device properties (save on a (2,2) mesh, restore onto (4,) and
(1,) meshes, QTensor component specs preserved, corrupted-shard detection)
need more devices than the pytest process has — tests/conftest.py pins the
real 1-CPU backend on purpose — so they run in ONE child process under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(tests/helpers/sharded_ckpt_child.py) and the tests here assert on its
per-check markers. Everything single-device runs in-process.
"""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager,
                                   restore_sharded_checkpoint,
                                   save_sharded_checkpoint)
from repro.core.quant import QuantConfig, quantize_tensor

HELPER = pathlib.Path(__file__).parent / "helpers" / "sharded_ckpt_child.py"


# ---------------- multi-device property checks (child process) ----------------

@pytest.fixture(scope="module")
def child_output(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("sharded_ckpt")
    proc = subprocess.run(
        [sys.executable, str(HELPER), str(tmp)],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"sharded-ckpt child failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.mark.parametrize("marker", [
    "remesh_2x2_to_4",          # (2,2) save -> (4,) restore, QTensor specs
    "remesh_2x2_to_1",          # (2,2) save -> single-device restore
    "local_assembly",           # shardings=None host-local restore
    "manager_param_specs_roundtrip",  # async sharded manager + remesh_restore
    "corruption_names_file",    # flipped shard bytes -> IOError names file
    "missing_manifest_detected",  # lost host shard manifest -> IOError
])
def test_multi_device_property(child_output, marker):
    assert f"OK {marker}" in child_output, child_output


# ---------------- single-device (degenerate mesh) paths ----------------

def _tree(key):
    return {
        "a": jax.random.normal(key, (8, 4)),
        "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.int32)},
        "tup": (jnp.ones(3), jnp.zeros(2)),
        "none": None,
        "qt": quantize_tensor(jax.random.normal(key, (64, 8)),
                              QuantConfig(bits=2, group_size=32)),
    }


def test_sharded_roundtrip_single_device(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    d = save_sharded_checkpoint(tmp_path, 5, tree)
    assert (d / "manifest.json").exists()
    assert (d / "shards_host0000.json").exists()
    restored, manifest = restore_sharded_checkpoint(tmp_path, 5, None)
    assert manifest["format"] == 2 and manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["none"] is None
    assert isinstance(restored["tup"], tuple)
    np.testing.assert_allclose(np.asarray(restored["qt"].dequantize()),
                               np.asarray(tree["qt"].dequantize()))


def test_sharded_roundtrip_bfloat16(tmp_path):
    """Regression: npz round-trips extension dtypes as raw void — shards are
    stored as bytes and viewed back through the manifest dtype, so the bf16
    param configs (yi-6b, phi3.5-moe, ...) checkpoint correctly."""
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 4)).astype(jnp.bfloat16)
    save_sharded_checkpoint(tmp_path, 1, {"w": w, "s": jnp.float16(2.5)})
    restored, _ = restore_sharded_checkpoint(tmp_path, 1, None)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"]).view(np.uint16),
        np.asarray(w).view(np.uint16))
    assert restored["s"].dtype == jnp.float16
    assert float(restored["s"]) == 2.5


def test_manager_wait_surfaces_async_save_failure(tmp_path, monkeypatch):
    """Regression: a failure on the writer thread must re-raise from wait()
    — a silently-dead daemon would let run_resilient log ('saved', step) for
    a checkpoint that never committed."""
    from repro.ckpt import checkpoint as ckpt_mod

    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones(3)})
    mgr.wait()

    def boom(*a, **kw):
        raise TimeoutError("shard manifests never landed")

    monkeypatch.setattr(ckpt_mod, "_write_full", boom)
    mgr.save(2, {"w": jnp.ones(3)})
    with pytest.raises(IOError, match="async checkpoint save failed"):
        mgr.wait()
    monkeypatch.undo()
    # the error is consumed: the manager stays usable afterwards
    mgr.save(3, {"w": jnp.ones(3)})
    mgr.wait()
    assert (tmp_path / "step_00000003" / "manifest.json").exists()


def test_restore_checkpoint_reads_format2(tmp_path):
    """The format-1 entry point must transparently restore format-2 saves
    (host-locally), so old callers keep working against new checkpoints."""
    from repro.ckpt.checkpoint import restore_checkpoint
    save_sharded_checkpoint(tmp_path, 2, {"w": jnp.arange(6.0)})
    tree, manifest = restore_checkpoint(tmp_path, 2)
    assert manifest["format"] == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(6.0))


def test_manager_sharded_async_gc_and_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, sharded=True)
    for step in (1, 2, 3):
        mgr.save(step, {"w": jnp.full((4,), float(step))})
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [2, 3]
    tree, manifest = mgr.restore()
    assert manifest["step"] == 3 and float(tree["w"][0]) == 3.0


def _fake_second_host(step_dir, host_id):
    """Clone host 0's shard files under another host id (a 2-host layout
    fabricated on one machine — the gc test only needs the filenames)."""
    import shutil
    shutil.copy(step_dir / "host0000.npz", step_dir / f"host{host_id:04d}.npz")
    shutil.copy(step_dir / "shards_host0000.json",
                step_dir / f"shards_host{host_id:04d}.json")


def test_manager_sharded_parallel_gc_two_hosts(tmp_path, monkeypatch):
    """Sharded gc is per-host-parallel: each host unlinks only ITS OWN shard
    files (host 1 leaves the manifest and host 0's shards alone), process 0
    uncommits the manifest, and whoever finishes last wins the rmdir."""
    for step in (1, 2, 3):
        save_sharded_checkpoint(tmp_path, step, {"w": jnp.full((4,), 1.0)})
        _fake_second_host(tmp_path / f"step_{step:08d}", 1)
    mgr = CheckpointManager(tmp_path, keep=2, sharded=True)
    old = tmp_path / "step_00000001"

    monkeypatch.setattr(jax, "process_index", lambda: 1)
    monkeypatch.setattr(jax, "process_count", lambda: 2)
    mgr._gc()
    # host 1 dropped its own shards; the step is still committed + readable
    # for host 0's restore until process 0 removes the manifest
    assert not (old / "host0001.npz").exists()
    assert not (old / "shards_host0001.json").exists()
    assert (old / "manifest.json").exists()
    assert (old / "host0000.npz").exists()

    monkeypatch.setattr(jax, "process_index", lambda: 0)
    mgr._gc()
    assert not old.exists()                     # last host wins the rmdir
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [2, 3]
    restored, manifest = restore_sharded_checkpoint(tmp_path, None, None)
    assert manifest["step"] == 3 and float(restored["w"][0]) == 1.0


def test_manager_sharded_gc_sweeps_shrunk_hosts(tmp_path, monkeypatch):
    """Process 0 sweeps shard files of host ids >= process_count: a save
    from a larger mesh must not pin its step directory forever after the
    job shrinks (nobody owns those files any more)."""
    for step in (1, 2, 3):
        save_sharded_checkpoint(tmp_path, step, {"w": jnp.full((4,), 1.0)})
    _fake_second_host(tmp_path / "step_00000001", 1)
    _fake_second_host(tmp_path / "step_00000001", 2)
    monkeypatch.setattr(jax, "process_index", lambda: 0)
    monkeypatch.setattr(jax, "process_count", lambda: 1)
    mgr = CheckpointManager(tmp_path, keep=2, sharded=True)
    mgr._gc()
    assert not (tmp_path / "step_00000001").exists()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [2, 3]


# ---------------- the ISSUE 5 bugfix: verify on the async manager path ----------------

def test_manager_restore_verifies_checksum_and_names_file(tmp_path):
    """Regression: the async (CheckpointManager) restore path must verify the
    manifest checksums like the direct functions do, and the corruption
    error must NAME THE FILE."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(4, {"w": jnp.arange(8.0)})
    mgr.wait()
    f = tmp_path / "step_00000004" / "host0000.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(Exception) as ei:
        mgr.restore(4)
    assert "host0000.npz" in str(ei.value) or "corrup" in str(ei.value).lower()


def test_manager_sharded_restore_verifies_checksum(tmp_path):
    mgr = CheckpointManager(tmp_path, sharded=True)
    mgr.save(1, {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 8))})
    mgr.wait()
    f = tmp_path / "step_00000001" / "host0000.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError) as ei:
        mgr.restore(1)
    assert "host0000.npz" in str(ei.value)


def test_manager_async_snapshot_handles_qtensor(tmp_path):
    """Regression: manager.save used to np.asarray() whole QTensor leaves in
    its donation-safety snapshot, which cannot represent the packed
    components; the snapshot now flattens component-wise."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(jax.random.PRNGKey(1))
    mgr.save(9, tree)
    mgr.wait()
    restored, manifest = mgr.restore(9)
    assert manifest["step"] == 9
    np.testing.assert_allclose(np.asarray(restored["qt"].dequantize()),
                               np.asarray(tree["qt"].dequantize()))
    assert restored["qt"].bits == tree["qt"].bits
