"""Observability layer: registry semantics, exact merges, span tracing, and
the no-cross-run-leakage contract on a reused batcher.

The merge tests pin the property everything multi-host rests on: with FIXED
bucket edges a histogram merge is a bucket-wise integer add, so merging is
exact, associative and commutative — ``dist_snapshot`` can fold per-host
snapshots in any grouping and every host lands on the identical aggregate.
"""
import io
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.dist.fault import StepWatchdog
from repro.obs.registry import hist_quantile


def _registry_with(counter=0.0, gauges=(), hist_obs=()):
    r = obs.Registry()
    if counter:
        r.counter("c_total").inc(counter)
    g = r.gauge("g")
    for v in gauges:
        g.set(v)
    h = r.histogram("h_seconds", buckets=(0.1, 1.0, 10.0))
    for v in hist_obs:
        h.observe(v)
    return r


# ---------------------------------------------------------------------------
# merge semantics
# ---------------------------------------------------------------------------

def test_histogram_bucket_add_is_exact_and_associative():
    """Integer bucket counts add exactly; (a+b)+c == a+(b+c) == (a+c)+b."""
    snaps = [
        _registry_with(hist_obs=[0.05] * 3 + [5.0]).snapshot(),
        _registry_with(hist_obs=[0.5, 0.5, 100.0]).snapshot(),
        _registry_with(hist_obs=[0.2] * 7).snapshot(),
    ]
    m = obs.merge_snapshots
    ab_c = m(m(snaps[0], snaps[1]), snaps[2])
    a_bc = m(snaps[0], m(snaps[1], snaps[2]))
    ac_b = m(m(snaps[0], snaps[2]), snaps[1])

    def series(snap):
        s = snap["h_seconds"]["series"][0]
        return (s["counts"], s["count"])   # the integer part: EXACT

    assert series(ab_c) == series(a_bc) == series(ac_b)
    # the float sum is order-sensitive in the last ulp — approx only
    assert a_bc["h_seconds"]["series"][0]["sum"] == pytest.approx(
        ab_c["h_seconds"]["series"][0]["sum"])
    s = ab_c["h_seconds"]["series"][0]
    assert s["counts"] == [3, 9, 1, 1]     # per-bucket integer adds
    assert s["count"] == 14
    assert s["sum"] == pytest.approx(3 * 0.05 + 2 * 0.5 + 100.0 + 7 * 0.2 + 5.0)


def test_histogram_merge_rejects_mismatched_edges():
    a = obs.Registry()
    a.histogram("h_seconds", buckets=(0.1, 1.0)).observe(0.5)
    b = obs.Registry()
    b.histogram("h_seconds", buckets=(0.2, 2.0)).observe(0.5)
    with pytest.raises(ValueError, match="edges"):
        obs.merge_snapshots(a.snapshot(), b.snapshot())


def test_counter_and_gauge_merge():
    a = _registry_with(counter=3, gauges=[7.0]).snapshot()
    b = _registry_with(counter=4, gauges=[2.0]).snapshot()
    c = _registry_with(counter=5, gauges=[4.0]).snapshot()
    m = obs.merge_snapshots(obs.merge_snapshots(a, b), c)
    assert m["c_total"]["series"][0]["value"] == 12.0
    g = m["g"]["series"][0]
    assert (g["min"], g["max"], g["sum"], g["n"]) == (2.0, 7.0, 13.0, 3)


def test_counter_merge_keeps_label_series_separate():
    a = obs.Registry()
    a.counter("req_total").inc(2, route="x")
    b = obs.Registry()
    b.counter("req_total").inc(3, route="x")
    b.counter("req_total").inc(1, route="y")
    m = obs.merge_snapshots(a.snapshot(), b.snapshot())
    got = {tuple(s["labels"].items()): s["value"]
           for s in m["req_total"]["series"]}
    assert got == {(("route", "x"),): 5.0, (("route", "y"),): 1.0}


def test_merge_with_empty_is_identity():
    a = _registry_with(counter=3, gauges=[1.0], hist_obs=[0.5]).snapshot()
    assert obs.merge_snapshots(a, {}) == obs.merge_snapshots({}, a)
    assert obs.snapshot_json(obs.merge_snapshots(a, {})) == obs.snapshot_json(
        obs.merge_snapshots({}, a))


# ---------------------------------------------------------------------------
# quantiles + exposition
# ---------------------------------------------------------------------------

def test_quantile_within_one_bucket_width():
    edges = (0.01, 0.02, 0.05, 0.1, 0.5)
    r = obs.Registry()
    h = r.histogram("lat", buckets=edges)
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.0, 0.4, size=500)
    for s in samples:
        h.observe(float(s))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.quantile(q)
        # the estimate interpolates within the rank's bucket, so it can be
        # off by at most that bucket's width
        widths = np.diff((0.0,) + edges)
        assert abs(est - exact) <= widths.max() + 1e-9


def test_hist_quantile_edge_cases():
    assert hist_quantile([0, 0, 0], (0.1, 1.0), 0.5) == 0.0   # empty
    # all mass in +Inf clamps to the largest finite edge
    assert hist_quantile([0, 0, 5], (0.1, 1.0), 0.5) == 1.0


def test_prometheus_exposition_format():
    r = _registry_with(counter=2, gauges=[3.0], hist_obs=[0.05, 0.5, 50.0])
    text = r.render_prometheus()
    assert "# TYPE c_total counter" in text
    assert "# TYPE h_seconds histogram" in text
    assert 'h_seconds_bucket{le="0.1"} 1' in text
    assert 'h_seconds_bucket{le="1.0"} 2' in text      # cumulative
    assert 'h_seconds_bucket{le="+Inf"} 3' in text
    assert "h_seconds_count 3" in text


# ---------------------------------------------------------------------------
# registry contracts
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_guard():
    r = obs.Registry()
    c1 = r.counter("x_total")
    assert r.counter("x_total") is c1
    with pytest.raises(TypeError):
        r.gauge("x_total")
    r.histogram("h", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        r.histogram("h", buckets=(1.0, 3.0))


def test_reset_zeroes_in_place_keeping_handles():
    r = obs.Registry()
    c = r.counter("x_total")
    h = r.histogram("h_seconds")
    c.inc(5)
    h.observe(0.1)
    r.reset()
    assert c.total() == 0.0 and h.count() == 0
    c.inc(2)       # the PRE-reset handle must still feed the registry
    assert r.snapshot()["x_total"]["series"][0]["value"] == 2.0


def test_counter_rejects_negative():
    with pytest.raises(ValueError):
        obs.Registry().counter("x_total").inc(-1)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_trace_span_writes_jsonl_and_observes_hist():
    r = obs.Registry()
    h = r.histogram("span_seconds")
    buf = io.StringIO()
    with obs.trace_to(buf):
        with obs.trace_span("unit", hist=h, k=1) as sp:
            pass
        obs.emit("ev", _print=False, a=2)
    events = [json.loads(line) for line in buf.getvalue().splitlines()]
    # every sink opens with the epoch anchor metadata event: event ts values
    # are monotonically derived, the anchor maps them back to wall time
    assert [e["ph"] for e in events] == ["M", "B", "E", "i"]
    assert events[0]["name"] == "clock_anchor"
    assert {"wall", "mono"} <= set(events[0])
    assert events[1]["attrs"] == {"k": 1}
    assert events[2]["dur_s"] == sp.dur and sp.dur >= 0.0
    # E.ts is derived from B.ts + dur, so spans can never overlap/reorder
    # under a wall-clock adjustment
    # abs tolerance: double precision at epoch magnitude is ~1e-7 s
    assert events[2]["ts"] - events[1]["ts"] == pytest.approx(sp.dur,
                                                              abs=1e-5)
    assert events[3]["a"] == 2
    assert h.count() == 1
    assert obs.get_trace_sink() is not buf    # trace_to restored the sink


def test_trace_span_records_error_and_no_sink_is_safe():
    buf = io.StringIO()
    with obs.trace_to(buf):
        with pytest.raises(RuntimeError):
            with obs.trace_span("boom"):
                raise RuntimeError("x")
    end = json.loads(buf.getvalue().splitlines()[-1])
    assert "error" in end and "RuntimeError" in end["error"]
    with obs.trace_span("quiet") as sp:   # no sink configured: still times
        pass
    assert sp.dur is not None


# ---------------------------------------------------------------------------
# snapshot files + single-process dist path
# ---------------------------------------------------------------------------

def test_write_snapshot_name_level_merge(tmp_path):
    p = tmp_path / "metrics.json"
    a = obs.Registry()
    a.counter("c_total").inc(3)
    obs.write_snapshot(obs.dist_snapshot(a), path=p)
    b = obs.Registry()
    b.gauge("other").set(1.0)
    b.counter("c_total").inc(9)           # same name: row-level REPLACE
    obs.write_snapshot(obs.dist_snapshot(b), path=p)
    d = json.loads(p.read_text())
    assert set(d) == {"c_total", "other"}
    assert d["c_total"]["series"][0]["value"] == 9.0


def test_dist_snapshot_single_process_normalizes():
    """The fast path must return the same mergeable schema the gather path
    does (gauges as min/max/sum/n), so downstream merges never special-case
    host count."""
    r = _registry_with(counter=2, gauges=[4.0], hist_obs=[0.5])
    snap = obs.dist_snapshot(r)
    g = snap["g"]["series"][0]
    assert (g["min"], g["max"], g["sum"], g["n"]) == (4.0, 4.0, 4.0, 1)
    assert obs.merge_snapshots(snap, snap)["c_total"]["series"][0][
        "value"] == 4.0
    assert jax.process_count() == 1       # the path this test pins


# ---------------------------------------------------------------------------
# instrumented components
# ---------------------------------------------------------------------------

def test_watchdog_exports_median_samples_and_trips():
    reg = obs.get_registry()
    reg.reset()
    wd = StepWatchdog(threshold=2.0, warmup=3)
    assert wd.median_step is None and wd.samples_seen == 0
    st = wd.stats()
    assert st["warmed_up"] is False and st["samples_seen"] == 0
    for _ in range(5):
        wd.observe(0.1)
    assert wd.observe(1.0) is True        # straggler
    assert wd.samples_seen == 5           # flagged samples stay out
    assert wd.stats()["warmed_up"] is True
    assert reg.counter("dist_watchdog_trips_total").total() == 1
    assert reg.gauge("dist_watchdog_median_step_seconds").value() == \
        pytest.approx(0.1)
    assert reg.gauge("dist_watchdog_samples_seen").value() == 5
    assert reg.histogram("dist_step_seconds").count() == 6  # ALL samples


def test_batcher_registry_reset_between_runs():
    """The satellite-6 bug: per-run latency state must not accumulate across
    ``run()`` calls on a reused batcher. After a registry reset, the TTFT
    histogram reflects ONLY the post-reset run."""
    from repro.configs import get_config
    from repro.core.quant import QuantConfig
    from repro.models import init_params
    from repro.quantized.qmodel import pack_model
    from repro.serving import ContinuousBatcher, PagedKVCache, PagedRequest

    cfg = get_config("opt-tiny").reduced(n_layers=1, d_model=32, d_ff=64,
                                         vocab_size=128, n_heads=2,
                                         n_kv_heads=2)
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    reg = obs.get_registry()
    reg.reset()
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2)

    def reqs(n):
        rng = np.random.default_rng(7)
        return [PagedRequest(prompt=rng.integers(
            0, cfg.vocab_size, size=5).astype(np.int32), max_new=2)
            for _ in range(n)]

    b.run(reqs(3))
    assert b.obs["ttft"].count() == 3
    assert len(b.done) == 3
    steps_run1 = b.stats["steps"]
    reg.reset()
    b.run(reqs(2))               # reused batcher, pre-reset handles
    assert b.obs["ttft"].count() == 2, "TTFT leaked across runs"
    assert len(b.done) == 2, "done list leaked across runs"
    assert not b._t_submit, "submit stamps leaked across runs"
    # the counter was zeroed mid-lifetime, so it holds run 2 only, while the
    # legacy stats dict keeps accumulating — exactly the split we want
    assert reg.counter("serving_decode_steps_total").total() == \
        b.stats["steps"] - steps_run1
    assert b.stats["prefills"] == 5


def test_search_metrics_reconcile_with_stats():
    """Counters must reconcile EXACTLY with the engine's legacy stats dict
    (the acceptance criterion the launch driver also asserts inline)."""
    from repro.configs import get_config
    from repro.core.quant import QuantConfig
    from repro.core.search import SearchConfig, run_search
    from repro.models import init_params

    reg = obs.get_registry()
    reg.reset()
    cfg = get_config("opt-tiny").reduced(n_layers=1, d_model=32, d_ff=64,
                                         vocab_size=128, n_heads=2,
                                         n_kv_heads=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                               cfg.vocab_size)
    scfg = SearchConfig(steps=4, seed=0, n_match_layers=1, log_every=0,
                        population=2, islands=2, migrate_every=2)
    r = run_search(params, params, cfg, QuantConfig(bits=2, group_size=32),
                   calib, scfg)
    assert reg.counter("search_proposals_total").total() == \
        r.stats["proposals"] == 4 * 2 * 2
    assert reg.counter("search_uphill_accepts_total").total() == \
        r.stats["uphill_accepts"]
    assert reg.counter("search_migrations_total").total() == \
        r.stats["migrations"]
    assert reg.histogram("search_step_seconds").count() == 4
    assert reg.histogram("search_eval_seconds").count() == 4 * 2
    assert reg.gauge("search_objective_best").value() == \
        pytest.approx(r.final_loss)
