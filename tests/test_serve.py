"""Quantized serving path: packed == fake-quant equivalence, batched server,
paged continuous-batching server, memory accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.core.rtn import rtn_quantize
from repro.launch.serve import BatchedServer, PagedServer, Request
from repro.models import init_params, forward
from repro.quantized.qmodel import pack_model


@pytest.fixture(scope="module")
def served():
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4, n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qcfg = QuantConfig(bits=2, group_size=32)
    return cfg, params, qcfg


def test_packed_forward_equals_fake_quant(served):
    """forward(pack(params)) == forward(fake_quant(params)) — the serving
    path (QTensor dequant inside scan) is numerically the fake-quant model."""
    cfg, params, qcfg = served
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    fq = forward(rtn_quantize(params, qcfg), cfg, tokens)
    packed = forward(pack_model(params, qcfg), cfg, tokens)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(fq),
                               rtol=2e-3, atol=2e-3)


def test_greedy_decode_matches_full_forward(served):
    """Server tokens == argmax chain from repeated full forwards."""
    cfg, params, qcfg = served
    params_q = pack_model(params, qcfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    server = BatchedServer(params_q, cfg, batch_size=1, max_len=64)
    out = server.generate([Request(prompt=prompt, max_new=5)])[0]

    seq = list(prompt)
    ref = []
    for _ in range(5):
        logits = forward(params_q, cfg, jnp.asarray([seq], dtype=jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        ref.append(nxt)
        seq.append(nxt)
    assert out == ref, f"server {out} != reference {ref}"


def test_batched_server_consistency(served):
    """Batching must not change per-request outputs (same prompt lengths)."""
    cfg, params, qcfg = served
    params_q = pack_model(params, qcfg)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
                    max_new=4) for _ in range(3)]
    single = BatchedServer(params_q, cfg, batch_size=1, max_len=64)
    batched = BatchedServer(params_q, cfg, batch_size=3, max_len=64)
    outs_1 = [single.generate([r])[0] for r in reqs]
    outs_b = batched.generate(reqs)
    assert outs_1 == outs_b


def test_paged_server_mixed_length_stream(served):
    """Acceptance: launch/serve.py serves a MIXED-length request stream
    through the continuous batcher, each request matching its own greedy
    chain (no cross-contamination between slots at different depths)."""
    cfg, params, qcfg = served
    params_q = pack_model(params, qcfg)
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(n)).astype(np.int32),
                    max_new=int(m))
            for n, m in [(3, 6), (11, 2), (7, 4), (16, 5), (5, 3)]]
    server = PagedServer(params_q, cfg, max_batch=3, page_size=8, max_len=64)
    outs = server.generate(reqs)
    for r, out in zip(reqs, outs):
        seq = list(r.prompt)
        ref = []
        for _ in range(r.max_new):
            logits = forward(params_q, cfg, jnp.asarray([seq], dtype=jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
            ref.append(nxt)
            seq.append(nxt)
        assert out == ref, f"paged {out} != greedy reference {ref}"
    # continuous batching actually interleaved work, then reclaimed all pages
    assert server.batcher.stats["prefills"] == len(reqs)
    alloc = server.cache.allocator
    assert alloc.num_free == alloc.n_pages - alloc.reserved


def test_memory_saving_at_scale():
    """At realistic dims the 2-bit packing saves >5x on quantized leaves."""
    qcfg = QuantConfig(bits=2, group_size=128)
    from repro.core.quant import quantize_tensor
    w = jax.random.normal(jax.random.PRNGKey(0), (2048, 2048))
    qt = quantize_tensor(w, qcfg)
    dense = w.size * 2  # bf16
    assert dense / qt.memory_bytes() > 5.0
