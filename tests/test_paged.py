"""Paged serving subsystem: kernel vs dense oracle, allocator, batcher.

The acceptance bar (ISSUE 2): ``paged_decode_attention`` must match the
dense ``kernels/ref.py`` oracle to <=1e-5 with fp32 pages across page sizes,
ragged sequence lengths, and GQA head ratios; int8 pages match their own
explicit-dequant oracle to <=1e-5 and the fp path to the 5e-2 tolerance the
contiguous int8 cache already documents in test_kernels.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.kernels import paged_decode
from repro.kernels.paged_decode import paged_decode_gqa_pallas
from repro.kernels.ref import flash_decode_ref, paged_decode_ref
from repro.models import forward, init_params, prefill
from repro.quantized.qmodel import pack_model, cache_bytes, serving_memory_report
from repro.serving import (ContinuousBatcher, NULL_PAGE, PageAllocator,
                           PagedKVCache, PagedRequest, make_paged_prefill_step)


def _random_paged(key, B, H, Hkv, Dh, page_size, n_pages, max_pages, int8=False):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, page_size, Hkv, Dh))
    vp = jax.random.normal(ks[2], (n_pages, page_size, Hkv, Dh))
    # distinct physical pages per sequence (disjoint live tables), padded
    # with the null page like the batcher does
    perm = jax.random.permutation(ks[3], n_pages - 1) + 1
    bt = np.zeros((B, max_pages), np.int32)
    flat = np.asarray(perm)[: B * max_pages]
    bt.flat[: flat.size] = flat
    bt = jnp.asarray(bt)
    lens = jax.random.randint(ks[4], (B,), 1, max_pages * page_size + 1)
    if not int8:
        return q, kp, vp, bt, lens, None, None
    kscale = jnp.max(jnp.abs(kp), axis=-1) / 127.0 + 1e-8
    vscale = jnp.max(jnp.abs(vp), axis=-1) / 127.0 + 1e-8
    k8 = jnp.round(kp / kscale[..., None]).astype(jnp.int8)
    v8 = jnp.round(vp / vscale[..., None]).astype(jnp.int8)
    return q, k8, v8, bt, lens, kscale, vscale


def _dense_oracle(q, kp, vp, bt, lens, ks, vs):
    """Gather pages into a contiguous cache, then the flash_decode oracle."""
    B, H, Dh = q.shape
    psz, Hkv = kp.shape[1], kp.shape[2]
    P = bt.shape[1]
    k = kp[bt].reshape(B, P * psz, Hkv, Dh).astype(jnp.float32)
    v = vp[bt].reshape(B, P * psz, Hkv, Dh).astype(jnp.float32)
    if ks is not None:
        k = k * ks[bt].reshape(B, P * psz, Hkv)[..., None]
        v = v * vs[bt].reshape(B, P * psz, Hkv)[..., None]
    if Hkv < H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    rows = [flash_decode_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                             kv_len=int(lens[b])) for b in range(B)]
    return jnp.concatenate(rows, axis=0)


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size", [4, 8, 16, 32])
def test_paged_decode_page_sizes(page_size):
    q, kp, vp, bt, lens, _, _ = _random_paged(
        page_size, B=3, H=4, Hkv=4, Dh=16, page_size=page_size,
        n_pages=3 * 3 + 1, max_pages=3)
    out = paged_decode(q, kp, vp, bt, lens)
    want = _dense_oracle(q, kp, vp, bt, lens, None, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4), st.sampled_from([4, 8, 16]), st.integers(1, 4),
       st.integers(0, 10_000))
def test_paged_decode_property(B, page_size, max_pages, seed):
    """Ragged lengths x page sizes x batch: kernel == gathered-dense oracle."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        seed, B=B, H=4, Hkv=4, Dh=8, page_size=page_size,
        n_pages=B * max_pages + 1, max_pages=max_pages)
    out = paged_decode(q, kp, vp, bt, lens)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("H,Hkv", [(8, 4), (8, 2), (4, 1)])
def test_paged_decode_gqa(H, Hkv):
    """Query head h must read KV head h // rep straight from the pool."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        7, B=2, H=H, Hkv=Hkv, Dh=16, page_size=8, n_pages=9, max_pages=4)
    out = paged_decode(q, kp, vp, bt, lens)
    want = _dense_oracle(q, kp, vp, bt, lens, None, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_int8_pages():
    """int8 codes + per-(slot, head) scales: exact vs the int8 oracle,
    ~5e-2 vs the fp pages they quantize (documented tolerance)."""
    q, k8, v8, bt, lens, ks, vs = _random_paged(
        11, B=2, H=4, Hkv=4, Dh=32, page_size=8, n_pages=9, max_pages=4,
        int8=True)
    out = paged_decode(q, k8, v8, bt, lens, ks, vs)
    want = paged_decode_ref(q, k8, v8, bt, lens, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    kp = k8.astype(jnp.float32) * ks[..., None]
    vp = v8.astype(jnp.float32) * vs[..., None]
    dense = _dense_oracle(q, kp, vp, bt, lens, None, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=5e-2, atol=5e-2)


def test_paged_decode_poisoned_dead_pages():
    """Positions past seq_len and block-table null-padding never leak."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        3, B=2, H=4, Hkv=4, Dh=16, page_size=8, n_pages=9, max_pages=4)
    want = paged_decode(q, kp, vp, bt, lens)
    # poison the null page and every slot past each sequence's length
    kp2, vp2 = kp.at[NULL_PAGE].set(500.0), vp.at[NULL_PAGE].set(500.0)
    psz = kp.shape[1]
    P = bt.shape[1]
    for b in range(q.shape[0]):
        used = int(lens[b])
        for p in range(P):
            for s in range(psz):
                if p * psz + s >= used:
                    pg = int(bt[b, p])
                    kp2 = kp2.at[pg, s].set(500.0)
                    vp2 = vp2.at[pg, s].set(500.0)
    out = paged_decode(q, kp2, vp2, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_partials_merge_across_shards():
    """normalize=False partials + dist.merge_partials == unsharded dense —
    a sequence-sharded cache can page each shard independently."""
    from repro.dist.attention import merge_partials
    psz, P = 8, 4
    q, kp, vp, bt, lens, _, _ = _random_paged(
        5, B=2, H=4, Hkv=4, Dh=16, page_size=psz, n_pages=9, max_pages=P)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    half = P // 2 * psz
    parts = [
        paged_decode(q, kp, vp, bt[:, : P // 2], jnp.minimum(lens, half),
                     normalize=False),
        paged_decode(q, kp, vp, bt[:, P // 2:],
                     jnp.maximum(lens - half, 0), normalize=False),
    ]
    merged = merge_partials(jnp.stack([p[0] for p in parts]),
                            jnp.stack([p[1] for p in parts]),
                            jnp.stack([p[2] for p in parts]))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("H,Hkv", [(8, 4), (8, 2), (4, 1), (8, 8)])
def test_paged_decode_gqa_fused_matches_oracle(H, Hkv):
    """The fused (B, Hkv, P)-grid kernel — one page DMA per KV head serving
    its whole query-head group — must match the dense oracle to <=1e-5,
    including the normalize=False LSE partials (the dist merge contract)."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        19, B=3, H=H, Hkv=Hkv, Dh=16, page_size=8, n_pages=13, max_pages=4)
    out = paged_decode_gqa_pallas(q, kp, vp, bt, lens, interpret=True)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    acc, m, l = paged_decode_gqa_pallas(q, kp, vp, bt, lens,
                                        normalize=False, interpret=True)
    acc_r, m_r, l_r = paged_decode_ref(q, kp, vp, bt, lens, normalize=False)
    for got, ref_ in ((acc, acc_r), (m, m_r), (l, l_r)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_),
                                   rtol=1e-5, atol=1e-5)


def test_paged_decode_gqa_fused_int8_and_ragged():
    """int8 pages + ragged lengths through the fused grid (dead-page skip
    included): exact vs the int8 oracle."""
    q, k8, v8, bt, lens, ks, vs = _random_paged(
        23, B=4, H=8, Hkv=2, Dh=32, page_size=4, n_pages=17, max_pages=4,
        int8=True)
    lens = jnp.asarray([1, 5, 9, 16], jnp.int32)  # 1 token .. full table
    out = paged_decode_gqa_pallas(q, k8, v8, bt, lens, ks, vs, interpret=True)
    want = paged_decode_ref(q, k8, v8, bt, lens, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_routes_gqa_to_fused():
    """ops.paged_decode must use the fused grid for GQA shapes by default
    and still match the per-query-head kernel (same math, one page read)."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        29, B=2, H=8, Hkv=2, Dh=16, page_size=8, n_pages=9, max_pages=4)
    fused = paged_decode(q, kp, vp, bt, lens)                   # default
    unfused = paged_decode(q, kp, vp, bt, lens, fused_gqa=False)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mp", [2, 4])
def test_paged_decode_gqa_multipage_matches_oracle(mp):
    """pages_per_block > 1 (the multi-page inner grid axis: MP pages staged
    into VMEM scratch, one (rep, MP*psz) online-softmax update per block)
    must match the oracle AND the single-page grid across ragged lengths —
    including a max_pages that MP does not divide (the last block is
    partially dead) and the normalize=False LSE partials."""
    q, kp, vp, bt, lens, _, _ = _random_paged(
        31, B=3, H=8, Hkv=2, Dh=16, page_size=8, n_pages=16, max_pages=5)
    base = paged_decode_gqa_pallas(q, kp, vp, bt, lens, interpret=True)
    out = paged_decode_gqa_pallas(q, kp, vp, bt, lens, interpret=True,
                                  pages_per_block=mp)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    got = paged_decode_gqa_pallas(q, kp, vp, bt, lens, interpret=True,
                                  pages_per_block=mp, normalize=False)
    ref_ = paged_decode_ref(q, kp, vp, bt, lens, normalize=False)
    for g, r in zip(got, ref_):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)


def test_paged_decode_gqa_multipage_int8_and_routing():
    """int8 pages through the multi-page grid (per-page dequant happens at
    stage time, before the block matmul), and the ops wrapper's
    ``gqa_pages_per_block`` knob routes to it."""
    q, k8, v8, bt, lens, ks, vs = _random_paged(
        37, B=4, H=8, Hkv=2, Dh=32, page_size=4, n_pages=17, max_pages=4,
        int8=True)
    lens = jnp.asarray([1, 5, 9, 16], jnp.int32)  # 1 token .. full table
    out = paged_decode_gqa_pallas(q, k8, v8, bt, lens, ks, vs,
                                  interpret=True, pages_per_block=2)
    want = paged_decode_ref(q, k8, v8, bt, lens, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    routed = paged_decode(q, k8, v8, bt, lens, ks, vs, gqa_pages_per_block=2)
    np.testing.assert_allclose(np.asarray(routed), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Chunked paged prefill (serving v2 admit path)
# ---------------------------------------------------------------------------

def _chunked_prefill(cfg, params_q, cache, page_ids, prompt, chunk_pages):
    """Drive make_paged_prefill_step over a prompt; returns last-token
    logits. Mutates cache.pools exactly like the batcher's admit."""
    psz = cache.page_size
    step = jax.jit(make_paged_prefill_step(cfg))
    bt = jnp.asarray(cache.block_table_row(page_ids)[None])
    plen = len(prompt)
    off = 0
    logits = last_off = None
    while off < plen:
        n_tok = min(chunk_pages * psz, plen - off)
        c = cache.pages_for(n_tok) * psz
        toks = np.zeros((1, c), np.int32)
        toks[0, :n_tok] = prompt[off: off + n_tok]
        logits, cache.pools = step(params_q, jnp.asarray(toks), cache.pools,
                                   bt, jnp.int32(off))
        last_off, off = off, off + n_tok
    return logits[0, (plen - 1) - last_off]


@pytest.mark.parametrize("page_size,plen,chunk_pages,n_kv",
                         [(4, 3, 2, 4),    # sub-page prompt
                          (8, 8, 1, 4),    # exact page multiple, 1-page chunks
                          (8, 13, 2, 2),   # ragged tail + GQA 2x
                          (4, 21, 4, 1),   # many chunks + GQA 4x
                          (16, 9, 2, 4)])  # page bigger than half the prompt
def test_paged_prefill_matches_contiguous_scatter(page_size, plen, chunk_pages,
                                                  n_kv):
    """Acceptance: chunked paged prefill == contiguous prefill +
    ``write_prefill`` scatter to <=1e-5 on the K/V pool contents (live token
    rows) AND on next-token logits, across ragged prompt lengths, page sizes
    and GQA ratios."""
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=n_kv)
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))
    prompt = np.random.default_rng(plen).integers(
        0, cfg.vocab_size, size=plen).astype(np.int32)
    def mk():
        return PagedKVCache(cfg, n_pages=16, page_size=page_size,
                            max_pages_per_seq=8)
    # reference: the v1 admit path (contiguous prefill, then scatter)
    ref_cache = mk()
    n_pages = ref_cache.pages_for(plen)
    ids = ref_cache.allocator.alloc(n_pages)
    s_pad = n_pages * page_size
    toks = np.zeros((1, s_pad), np.int32)
    toks[0, :plen] = prompt
    logits_ref, kv = prefill(params_q, cfg, jnp.asarray(toks), s_pad)
    ref_cache.write_prefill(ids, kv, plen)
    # v2: chunks written straight into the same page ids
    new_cache = mk()
    assert new_cache.allocator.alloc(n_pages) == ids
    last = _chunked_prefill(cfg, params_q, new_cache, ids, prompt, chunk_pages)
    want = ref_cache.gather_tokens(ids, plen)
    got = new_cache.gather_tokens(ids, plen)
    for key in want:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=1e-5, atol=1e-5, err_msg=key)
    np.testing.assert_allclose(
        np.asarray(last[: cfg.vocab_size]),
        np.asarray(logits_ref[0, plen - 1, : cfg.vocab_size]),
        rtol=1e-5, atol=1e-4)


def test_paged_prefill_int8_pool_matches_scatter():
    """int8 pools: the chunk writer must quantize with the same per-(slot,
    head) convention as the contiguous cache, code-for-code."""
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=4)
    cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))
    prompt = np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=11).astype(np.int32)
    def mk():
        return PagedKVCache(cfg, n_pages=12, page_size=4,
                            max_pages_per_seq=6)
    ref_cache, new_cache = mk(), mk()
    ids = ref_cache.allocator.alloc(ref_cache.pages_for(11))
    assert new_cache.allocator.alloc(len(ids)) == ids
    toks = np.zeros((1, len(ids) * 4), np.int32)
    toks[0, :11] = prompt
    _, kv = prefill(params_q, cfg, jnp.asarray(toks), len(ids) * 4)
    ref_cache.write_prefill(ids, kv, 11)
    _chunked_prefill(cfg, params_q, new_cache, ids, prompt, chunk_pages=2)
    want = ref_cache.gather_tokens(ids, 11)
    got = new_cache.gather_tokens(ids, 11)
    assert got["k"].dtype == jnp.int8
    for key in want:  # int8 codes must agree exactly, scales to fp tolerance
        np.testing.assert_allclose(np.asarray(got[key], np.float32),
                                   np.asarray(want[key], np.float32),
                                   rtol=1e-5, atol=2e-5, err_msg=key)


def test_admit_path_never_runs_contiguous_prefill(packed_tiny, monkeypatch):
    """Acceptance: no ``(1, s_pad)`` contiguous KV buffer on the admit path —
    the batcher must not call ``write_prefill`` (the scatter copy) nor
    ``models.prefill`` (the contiguous cache builder) at all."""
    cfg, params_q = packed_tiny

    def boom(*a, **k):
        raise AssertionError("contiguous prefill path used on admit")

    monkeypatch.setattr(PagedKVCache, "write_prefill", boom)
    monkeypatch.setattr("repro.models.model.prefill", boom)
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefill_chunk_pages=1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 12, 9)]
    outs = b.run([PagedRequest(prompt=p, max_new=3) for p in prompts])
    for p, out in zip(prompts, outs):
        assert out == _greedy_oracle(params_q, cfg, p, 3)
    assert b.stats["prefill_chunks"] >= sum(
        cache.pages_for(len(p)) for p in prompts)


def test_gqa_server_end_to_end_matches_greedy_oracle():
    """GQA config through the WHOLE v2 stack (chunked GQA prefill + fused
    GQA paged decode): every request equals its own greedy chain."""
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=2)
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefill_chunk_pages=2)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 9, 14)]
    outs = b.run([PagedRequest(prompt=p, max_new=4) for p in prompts])
    for p, out in zip(prompts, outs):
        assert out == _greedy_oracle(params_q, cfg, p, 4)


# ---------------------------------------------------------------------------
# Page allocator
# ---------------------------------------------------------------------------

def test_allocator_reuse_and_exhaustion():
    a = PageAllocator(n_pages=6)  # 5 usable (page 0 reserved)
    first = a.alloc(3)
    assert len(first) == 3 and NULL_PAGE not in first
    assert a.alloc(3) is None, "all-or-nothing: only 2 left"
    assert a.num_free == 2, "failed alloc must not leak pages"
    a.free(first)
    again = a.alloc(5)
    assert sorted(again) == sorted(set(again)), "no duplicate grants"
    assert set(first) <= set(again), "freed pages are reused"
    assert a.alloc(1) is None and a.num_free == 0


def test_allocator_rejects_double_free():
    a = PageAllocator(n_pages=4)
    ids = a.alloc(2)
    a.free(ids[:1])
    with pytest.raises(ValueError):
        a.free(ids[:1])
    with pytest.raises(ValueError):
        a.free([NULL_PAGE])  # the reserved page is never allocatable


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def packed_tiny():
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pack_model(params, QuantConfig(bits=2, group_size=32))


def _greedy_oracle(params_q, cfg, prompt, n):
    seq = list(prompt)
    out = []
    for _ in range(n):
        logits = forward(params_q, cfg, jnp.asarray([seq], dtype=jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(nxt)
        seq.append(nxt)
    return out


def test_batcher_admit_order_and_reclamation(packed_tiny):
    """More requests than slots: FIFO admission, per-request greedy outputs
    exact, and every page returns to the free list at the end."""
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 13, 3, 8)]  # 8 = exact page multiple
    outs = b.run([PagedRequest(prompt=p, max_new=4) for p in prompts])
    for p, out in zip(prompts, outs):
        assert out == _greedy_oracle(params_q, cfg, p, 4)
    assert b.stats["prefills"] == 5 and not b.queue
    assert cache.allocator.num_free == cache.n_pages - cache.allocator.reserved


def test_batcher_eviction_under_page_pressure(packed_tiny):
    """A pool too small for the offered load must preempt (newest first),
    re-admit, and still produce the exact greedy continuation."""
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=7, page_size=4, max_pages_per_seq=6)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=3)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 8, 11)]
    outs = b.run([PagedRequest(prompt=p, max_new=8) for p in prompts])
    assert b.stats["evictions"] >= 1, "this pool size must force preemption"
    for p, out in zip(prompts, outs):
        assert out == _greedy_oracle(params_q, cfg, p, 8)
    assert cache.allocator.num_free == cache.n_pages - cache.allocator.reserved


def test_batcher_int8_pages(packed_tiny):
    """int8 page pools serve end to end; memory accounting sees the pool."""
    cfg, params_q = packed_tiny
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    cache = PagedKVCache(cfg8, n_pages=16, page_size=8, max_pages_per_seq=4)
    assert set(cache.pools) == {"k", "v", "k_scale", "v_scale"}
    b = ContinuousBatcher(params_q, cfg8, cache, max_batch=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(3)]
    outs = b.run([PagedRequest(prompt=p, max_new=4) for p in prompts])
    assert all(len(o) == 4 for o in outs)
    rep = serving_memory_report(params_q, cache.pools)
    assert rep["kv_bytes"] == cache_bytes(cache.pools) == cache.pool_bytes()
    assert 0.0 < rep["kv_fraction"] < 1.0


def test_batcher_rejects_oversized_request(packed_tiny):
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=2)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2)
    with pytest.raises(ValueError):
        b.submit(PagedRequest(prompt=np.zeros(15, np.int32), max_new=4))
    with pytest.raises(ValueError, match="empty prompt"):
        b.submit(PagedRequest(prompt=np.zeros(0, np.int32), max_new=4))


def test_paged_cache_rejects_stateless_archs():
    cfg = get_config("mamba2-2.7b").reduced()
    with pytest.raises(ValueError):
        PagedKVCache(cfg, n_pages=8, page_size=8, max_pages_per_seq=2)


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_paged_step_lowers_from_dryrun_structs(kv_dtype):
    """The dryrun-facing specs (steps.paged_pool_structs + qparam_structs)
    must lower the paged decode step without allocating — and the structs
    must be the exact layout PagedKVCache allocates (derived, not
    duplicated)."""
    from repro.core.quant import QuantConfig as QC
    from repro.launch.steps import (make_paged_serve_step, paged_pool_structs,
                                    qparam_structs)
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=4)
    cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    B, P, n_pages, psz = 2, 3, 7, 8
    pools = paged_pool_structs(cfg, n_pages, psz)
    live = PagedKVCache(cfg, n_pages=n_pages, page_size=psz,
                        max_pages_per_seq=P).pools
    assert jax.tree.structure(pools) == jax.tree.structure(live)
    assert ([(s.shape, s.dtype) for s in jax.tree.leaves(pools)]
            == [(a.shape, a.dtype) for a in jax.tree.leaves(live)])
    args = (qparam_structs(cfg, QC(bits=2, group_size=32)),
            jax.ShapeDtypeStruct((B, 1), jnp.int32), pools,
            jax.ShapeDtypeStruct((B, P), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32))
    tok_s, pools_s = jax.eval_shape(make_paged_serve_step(cfg), *args)
    assert tok_s.shape == (B, 1)
    assert jax.tree.structure(pools_s) == jax.tree.structure(pools)


# ---------------------------------------------------------------------------
# Dead-page skipping (pl.when on page index vs sequence length)
# ---------------------------------------------------------------------------

def test_paged_decode_short_seqs_deep_pool_equivalence():
    """Short sequences in DEEP pools (many dead block-table slots) — the
    skip path (compute gated by pl.when, dead slots clamped to the last live
    page so no fresh DMA is issued) must be exactly equivalent to the dense
    oracle, including a one-token sequence in a 16-page table."""
    psz, P, B, H, Dh = 8, 16, 4, 4, 16
    q, kp, vp, bt, _, _, _ = _random_paged(
        21, B=B, H=H, Hkv=H, Dh=Dh, page_size=psz, n_pages=B * P + 1,
        max_pages=P)
    lens = jnp.asarray([1, psz, psz + 3, P * psz], jnp.int32)  # 1..full
    out = paged_decode(q, kp, vp, bt, lens)
    want = paged_decode_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # partials too (the dist merge contract must see identical (acc, m, l))
    acc, m, l = paged_decode(q, kp, vp, bt, lens, normalize=False)
    acc_r, m_r, l_r = paged_decode_ref(q, kp, vp, bt, lens, normalize=False)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_r), rtol=1e-5,
                               atol=1e-5)


def test_paged_decode_skipped_pages_never_read():
    """Poison every PHYSICAL page beyond each sequence's live count with NaN:
    the skip path must never let a NaN reach the output (NaN would survive
    any masking arithmetic, unlike the masked-softmax zeros)."""
    psz, P, B, H = 4, 8, 2, 2
    q, kp, vp, bt, _, _, _ = _random_paged(
        13, B=B, H=H, Hkv=H, Dh=8, page_size=psz, n_pages=B * P + 1,
        max_pages=P)
    lens = jnp.asarray([3, 2 * psz], jnp.int32)
    want = paged_decode(q, kp, vp, bt, lens)
    kp2, vp2 = kp, vp
    for b in range(B):
        n_live = -(-int(lens[b]) // psz)
        for p in range(n_live, P):
            pg = int(bt[b, p])
            kp2 = kp2.at[pg].set(jnp.nan)
            vp2 = vp2.at[pg].set(jnp.nan)
    out = paged_decode(q, kp2, vp2, bt, lens)
    assert not bool(jnp.any(jnp.isnan(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Sampling (temperature / top-k) in the paged decode step
# ---------------------------------------------------------------------------

def test_sampling_step_seeded_determinism(packed_tiny):
    """Same per-sequence keys => identical sampled tokens; different keys
    may differ; greedy step signature/output stays byte-identical."""
    from repro.serving import (make_paged_decode_step, sample_step_keys,
                               PagedKVCache)
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    B, P = 2, 4
    ids = cache.allocator.alloc(B * P)
    bt = jnp.asarray(np.asarray(ids).reshape(B, P), jnp.int32)
    lens = jnp.asarray([5, 9], jnp.int32)
    toks = jnp.asarray([[7], [11]], jnp.int32)
    greedy = jax.jit(make_paged_decode_step(cfg))
    sampled = jax.jit(make_paged_decode_step(cfg, temperature=0.8, top_k=8))
    keys = sample_step_keys(jax.random.PRNGKey(42), B)
    t1, _ = sampled(params_q, toks, cache.pools, bt, lens, keys)
    t2, _ = sampled(params_q, toks, cache.pools, bt, lens, keys)
    assert np.array_equal(np.asarray(t1), np.asarray(t2)), "seeded => identical"
    assert t1.shape == (B, 1) and t1.dtype == jnp.int32
    assert bool(jnp.all((t1 >= 0) & (t1 < cfg.vocab_size)))
    # greedy default: unchanged 5-arg signature and argmax selection
    tg, _ = greedy(params_q, toks, cache.pools, bt, lens)
    assert tg.shape == (B, 1)


def test_sampling_cold_temperature_is_greedy(packed_tiny):
    """T->0 and top_k=1 must both reproduce the greedy argmax exactly."""
    from repro.serving import (make_paged_decode_step, sample_step_keys,
                               PagedKVCache)
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    B, P = 2, 4
    ids = cache.allocator.alloc(B * P)
    bt = jnp.asarray(np.asarray(ids).reshape(B, P), jnp.int32)
    lens = jnp.asarray([4, 7], jnp.int32)
    toks = jnp.asarray([[3], [5]], jnp.int32)
    keys = sample_step_keys(jax.random.PRNGKey(0), B)
    tg, _ = jax.jit(make_paged_decode_step(cfg))(
        params_q, toks, cache.pools, bt, lens)
    t_cold, _ = jax.jit(make_paged_decode_step(cfg, temperature=1e-6))(
        params_q, toks, cache.pools, bt, lens, keys)
    t_top1, _ = jax.jit(make_paged_decode_step(cfg, temperature=5.0, top_k=1))(
        params_q, toks, cache.pools, bt, lens, keys)
    assert np.array_equal(np.asarray(tg), np.asarray(t_cold))
    assert np.array_equal(np.asarray(tg), np.asarray(t_top1))


def test_sample_logits_top_k_support():
    """top-k sampling never leaves the k highest logits."""
    from repro.serving import sample_logits, sample_step_keys
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)),
                         jnp.float32)
    top_rows = np.argsort(np.asarray(logits), axis=-1)[:, -8:]
    for seed in range(5):
        keys = sample_step_keys(jax.random.PRNGKey(seed), 4)
        toks = sample_logits(logits, keys, temperature=3.0, top_k=8)
        for b in range(4):
            assert int(toks[b]) in set(top_rows[b].tolist())


def test_sample_logits_per_seq_matches_static():
    """The per-sequence path must agree row-for-row with the static-config
    sampler at the same (key, temperature, top_k), and take the exact argmax
    on temperature <= 0 rows."""
    from repro.serving import (sample_logits, sample_logits_per_seq,
                               sample_step_keys)
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(4, 64)),
                         jnp.float32)
    keys = sample_step_keys(jax.random.PRNGKey(7), 4)
    temps = jnp.asarray([0.0, 0.8, 2.0, 0.8], jnp.float32)
    top_ks = jnp.asarray([0, 8, 0, 5], jnp.int32)
    got = sample_logits_per_seq(logits, keys, temps, top_ks)
    assert int(got[0]) == int(jnp.argmax(logits[0]))
    for b in (1, 2, 3):
        want = sample_logits(logits[b: b + 1], keys[b: b + 1],
                             temperature=float(temps[b]),
                             top_k=int(top_ks[b]))
        assert int(got[b]) == int(want[0])


# ---------------------------------------------------------------------------
# Per-request sampling through the batcher (serving v2)
# ---------------------------------------------------------------------------

def _mixed_requests(cfg, seed=6):
    rng = np.random.default_rng(seed)
    def mk(n):
        return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
    return [
        PagedRequest(prompt=mk(5), max_new=5),                      # greedy
        PagedRequest(prompt=mk(9), max_new=5, temperature=0.9,
                     top_k=16, seed=11),
        PagedRequest(prompt=mk(7), max_new=5, temperature=1.3, seed=12),
    ]


def test_batcher_mixed_greedy_and_sampled(packed_tiny):
    """Greedy and sampled requests share decode steps: the greedy request
    must still equal its greedy chain EXACTLY, sampled requests are
    deterministic in their seeds and stay in-vocab."""
    cfg, params_q = packed_tiny

    def serve():
        cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
        b = ContinuousBatcher(params_q, cfg, cache, max_batch=3)
        return b.run(_mixed_requests(cfg))

    outs1, outs2 = serve(), serve()
    assert outs1 == outs2, "same seeds => identical serve output"
    greedy_req = _mixed_requests(cfg)[0]
    assert outs1[0] == _greedy_oracle(params_q, cfg, greedy_req.prompt, 5)
    for out in outs1[1:]:
        assert len(out) == 5
        assert all(0 <= t < cfg.vocab_size for t in out)


def test_sampling_keys_survive_preemption(packed_tiny):
    """The SAME sampled streams must come out whether or not a request was
    recompute-preempted mid-generation: keys derive from (seed, token index),
    not from the schedule. A page-starved pool (forces evictions) and a roomy
    pool (none) must produce identical outputs."""
    cfg, params_q = packed_tiny

    def serve(n_pages, page_size, max_pages):
        cache = PagedKVCache(cfg, n_pages=n_pages, page_size=page_size,
                             max_pages_per_seq=max_pages)
        b = ContinuousBatcher(params_q, cfg, cache, max_batch=3)
        rng = np.random.default_rng(1)
        reqs = [PagedRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
            max_new=8, temperature=0.7, top_k=12, seed=100 + i)
            for i, n in enumerate((6, 8, 11))]
        return b.run(reqs), b.stats

    starved, stats_s = serve(n_pages=7, page_size=4, max_pages=6)
    roomy, stats_r = serve(n_pages=32, page_size=4, max_pages=6)
    assert stats_s["evictions"] >= 1, "starved pool must preempt"
    assert stats_r["evictions"] == 0
    assert starved == roomy, \
        "preemption must not fork a request's sample stream"


def test_sampling_preemption_padded_vocab_stream_identical():
    """Regression: with vocab_size NOT a multiple of vocab_pad_multiple the
    LM head emits padded-V logits; admit-time sampling must draw over the
    SAME full-width masked row as the jitted step (categorical draws depend
    on array width), or preemption forks the stream."""
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=250, n_heads=4,
                                         n_kv_heads=4)
    assert cfg.padded_vocab > cfg.vocab_size
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))

    def serve(n_pages):
        cache = PagedKVCache(cfg, n_pages=n_pages, page_size=4,
                             max_pages_per_seq=6)
        b = ContinuousBatcher(params_q, cfg, cache, max_batch=3)
        rng = np.random.default_rng(1)
        reqs = [PagedRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
            max_new=6, temperature=0.9, top_k=20, seed=40 + i)
            for i, n in enumerate((6, 8, 11))]
        return b.run(reqs), b.stats

    starved, stats_s = serve(n_pages=7)
    roomy, _ = serve(n_pages=32)
    assert stats_s["evictions"] >= 1
    assert starved == roomy
    assert all(0 <= t < cfg.vocab_size for out in starved for t in out)


def test_preempt_near_completion_respects_max_new(packed_tiny):
    """Regression (ISSUE 4): a request preempted one token short of its
    budget must re-admit, finish with EXACTLY max_new tokens (admit-time
    prefill must not over-append), and still match its greedy chain — with
    ``run()`` no longer truncating outputs."""
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=24, page_size=4, max_pages_per_seq=6)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 9)]
    reqs = [PagedRequest(prompt=p, max_new=4) for p in prompts]
    for r in reqs:
        b.submit(r)
    # run until the younger request is one token short of done
    while len(reqs[1].out) < reqs[1].max_new - 1:
        assert b.step() > 0
    # force recompute preemption of the newest (= reqs[1]) slot
    assert b._evict_newest()
    assert len(reqs[1].out) == reqs[1].max_new - 1
    while b.queue or any(s is not None for s in b.slots):
        b.step()
    assert b.stats["evictions"] >= 1
    for r, p in zip(reqs, prompts):
        assert len(r.out) == r.max_new, "generation must stop AT the budget"
        assert r.out == _greedy_oracle(params_q, cfg, p, r.max_new)


def test_admit_skips_already_complete_requests(packed_tiny):
    """A queued request whose budget is already spent (preempted at the
    finish line) must go straight to done — no prefill, no page churn."""
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2)
    done = PagedRequest(prompt=np.asarray([5, 7], np.int32), max_new=2,
                        out=[1, 2])
    b.queue.append(done)
    assert b._admit_one()
    assert done in b.done and done.out == [1, 2]
    assert b.stats["prefills"] == 0
    assert cache.allocator.num_free == cache.n_pages - cache.allocator.reserved
