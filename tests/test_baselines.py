"""GPTQ / AWQ / OmniQuant-lite baselines: each must beat plain RTN on the
metric it optimizes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QuantConfig, fake_quant
from repro.core.gptq import gptq_matrix
from repro.core.awq import awq_scale_ffn, clip_search
from repro.core.omniquant import _optimize_block, fake_quant_lwc


def test_gptq_beats_rtn_on_correlated_inputs():
    """GPTQ's whole point: with correlated activations, error compensation
    gives lower OUTPUT error than RTN even if weight error is higher."""
    key = jax.random.PRNGKey(0)
    K, N, n = 64, 32, 512
    w = jax.random.normal(key, (K, N))
    base = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    mix = jax.random.normal(jax.random.PRNGKey(2), (8, K))
    x = base @ mix + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (n, K))
    qcfg = QuantConfig(bits=2, group_size=32)
    w_gptq = gptq_matrix(w, x, qcfg.bits, qcfg.group_size)
    w_rtn = fake_quant(w, qcfg)
    err_gptq = float(jnp.mean(jnp.square(x @ w_gptq - x @ w)))
    err_rtn = float(jnp.mean(jnp.square(x @ w_rtn - x @ w)))
    assert err_gptq < err_rtn, f"gptq {err_gptq:.4f} !< rtn {err_rtn:.4f}"


def test_gptq_reduces_to_rtn_for_identity_hessian():
    """With orthogonal inputs (XᵀX ∝ I) the inverse-Hessian is diagonal, so
    GPTQ's compensation vanishes and it must equal plain RTN exactly."""
    key = jax.random.PRNGKey(1)
    K, N = 32, 16
    w = jax.random.normal(key, (K, N))
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(2), (K, K)))
    x = q.T * 3.0  # rows orthogonal: x.T @ x = 9 I
    qcfg = QuantConfig(bits=4, group_size=16)
    w_gptq = gptq_matrix(w, x, qcfg.bits, qcfg.group_size, damp=0.0)
    np.testing.assert_allclose(np.asarray(w_gptq),
                               np.asarray(fake_quant(w, qcfg)), atol=1e-4)


def test_awq_scaling_beats_plain_rtn():
    """AWQ scaling must reduce quantized FFN output MSE (ReLU => invariant)."""
    from repro.models.config import ModelConfig
    cfg = ModelConfig(activation="relu", gated_mlp=False)
    key = jax.random.PRNGKey(0)
    D, F, n = 32, 64, 256
    w_up = jax.random.normal(key, (D, F))
    # outlier hidden channels (what AWQ exists to fix)
    w_up = w_up.at[:, :4].mul(8.0)
    w_down = jax.random.normal(jax.random.PRNGKey(1), (F, D))
    b_up = jnp.zeros((F,))
    x = jax.random.normal(jax.random.PRNGKey(2), (n, D))
    qcfg = QuantConfig(bits=2, group_size=32)

    def out_err(wu, wd, bu):
        y_fp = jax.nn.relu(x @ w_up + b_up) @ w_down
        y = jax.nn.relu(x @ fake_quant(wu, qcfg) + bu) @ fake_quant(wd, qcfg)
        return float(jnp.mean(jnp.square(y - y_fp)))

    su, sd, sb, _, s = awq_scale_ffn(w_up, w_down, b_up, None, x, qcfg, cfg)
    assert out_err(su, sd, sb) <= out_err(w_up, w_down, b_up) + 1e-6


def test_clip_search_not_worse():
    key = jax.random.PRNGKey(0)
    K, N, n = 64, 32, 256
    w = jax.random.normal(key, (K, N))
    w = w.at[0, 0].set(20.0)  # outlier that wrecks the group scale
    x = jax.random.normal(jax.random.PRNGKey(1), (n, K))
    qcfg = QuantConfig(bits=2, group_size=32)
    wc = clip_search(w, x, qcfg.bits, qcfg.group_size)
    err_clip = float(jnp.mean(jnp.square(x @ fake_quant(wc, qcfg) - x @ w)))
    err_rtn = float(jnp.mean(jnp.square(x @ fake_quant(w, qcfg) - x @ w)))
    assert err_clip <= err_rtn + 1e-6


def test_omniquant_block_loss_decreases():
    key = jax.random.PRNGKey(0)
    D, F, n = 16, 32, 128
    w_up = jax.random.normal(key, (D, F))
    w_down = jax.random.normal(jax.random.PRNGKey(1), (F, D))
    x = jax.random.normal(jax.random.PRNGKey(2), (n, D))
    wu, wd, bu, losses = _optimize_block(
        w_up, w_down, jnp.zeros_like(w_up), jnp.zeros((F,)), x,
        bits=2, group_size=16, steps=60, gated=False, act_name="relu")
    assert float(losses[-1]) < float(losses[0]), "LWC+LET must reduce block MSE"


def test_fake_quant_lwc_matches_plain_at_identity():
    """sigmoid(+inf) == 1 recovers plain fake-quant."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    qcfg = QuantConfig(bits=4, group_size=32)
    big = jnp.full((2, 8), 50.0)
    np.testing.assert_allclose(
        np.asarray(fake_quant_lwc(w, qcfg, big, big)),
        np.asarray(fake_quant(w, qcfg)), rtol=1e-5, atol=1e-6)


def test_pipeline_methods_end_to_end(trained_tiny, calib):
    """All four base methods + search wire through quantize_model, and the
    paper's ordering holds: every calibrated method beats RTN at 2 bits."""
    from repro.core.pipeline import quantize_model
    from repro.core.objective import calib_ce
    from repro.models import forward
    params, cfg = trained_tiny
    qcfg = QuantConfig(bits=2, group_size=32)
    ce = {}
    for method in ("rtn", "awq", "gptq"):
        r = quantize_model(params, cfg, qcfg, method=method, calib_tokens=calib)
        ce[method] = float(calib_ce(forward(r.params_q, cfg, calib), calib,
                                    cfg.vocab_size))
    ce_fp = float(calib_ce(forward(params, cfg, calib), calib, cfg.vocab_size))
    assert ce_fp < ce["rtn"], "2-bit RTN must visibly hurt a trained model"
    assert ce["awq"] < ce["rtn"]
    assert ce["gptq"] < ce["rtn"]
