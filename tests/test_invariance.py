"""Invariance transforms: the paper's §3.2 equations, verified numerically."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.invariance import (FFNTransform, identity_transform,
                                   apply_transform_ffn, propose, ProposalConfig)


def _ffn(x, wu, wd, bu=None, wg=None, act=jax.nn.relu):
    up = x @ wu + (bu if bu is not None else 0.0)
    h = act(x @ wg) * up if wg is not None else act(up)
    return h @ wd


def _rand_ffn(key, D=24, F=32, bias=True, gate=False):
    ks = jax.random.split(key, 5)
    wu = jax.random.normal(ks[0], (D, F))
    wd = jax.random.normal(ks[1], (F, D))
    bu = jax.random.normal(ks[2], (F,)) if bias else None
    wg = jax.random.normal(ks[3], (D, F)) if gate else None
    x = jax.random.normal(ks[4], (6, D))
    return x, wu, wd, bu, wg


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_permutation_scaling_exact_relu(seed):
    """Eqns 8-15: P and S leave a ReLU FFN exactly invariant."""
    key = jax.random.PRNGKey(seed)
    x, wu, wd, bu, _ = _rand_ffn(key)
    F = wu.shape[1]
    k1, k2 = jax.random.split(key)
    t = FFNTransform(pi=jax.random.permutation(k1, F).astype(jnp.int32),
                     s=jnp.exp(jax.random.normal(k2, (F,)) * 0.5),
                     phi=jnp.zeros((F // 2,)))
    u, d, b, _, _ = apply_transform_ffn(t, wu, wd, bu)
    np.testing.assert_allclose(np.asarray(_ffn(x, u, d, b)),
                               np.asarray(_ffn(x, wu, wd, bu)),
                               rtol=2e-4, atol=2e-4)


def test_rotation_exact_for_linear_activation():
    """Rotation IS exact when f is the identity (Eqn 16 equality case)."""
    key = jax.random.PRNGKey(0)
    x, wu, wd, bu, _ = _rand_ffn(key)
    F = wu.shape[1]
    t = FFNTransform(pi=jnp.arange(F, dtype=jnp.int32), s=jnp.ones((F,)),
                     phi=jax.random.normal(key, (F // 2,)) * 2.0)
    u, d, b, _, _ = apply_transform_ffn(t, wu, wd, bu)
    def ident(v):
        return v
    np.testing.assert_allclose(np.asarray(_ffn(x, u, d, b, act=ident)),
                               np.asarray(_ffn(x, wu, wd, bu, act=ident)),
                               rtol=1e-4, atol=1e-4)


def test_small_rotation_approx_relu():
    """Paper pilot: tiny rotations change the ReLU model output negligibly."""
    key = jax.random.PRNGKey(1)
    x, wu, wd, bu, _ = _rand_ffn(key)
    F = wu.shape[1]
    t = FFNTransform(pi=jnp.arange(F, dtype=jnp.int32), s=jnp.ones((F,)),
                     phi=jax.random.normal(key, (F // 2,)) * 1e-5)
    u, d, b, _, _ = apply_transform_ffn(t, wu, wd, bu)
    z0 = _ffn(x, wu, wd, bu)
    rel = float(jnp.max(jnp.abs(_ffn(x, u, d, b) - z0)) / (jnp.max(jnp.abs(z0)) + 1e-9))
    assert rel < 1e-4


def test_gated_mlp_permutation_scaling_exact():
    """SwiGLU: same pi on gate+up+down, S on the linear up-branch — exact."""
    key = jax.random.PRNGKey(2)
    x, wu, wd, _, wg = _rand_ffn(key, bias=False, gate=True)
    F = wu.shape[1]
    k1, k2 = jax.random.split(key)
    t = FFNTransform(pi=jax.random.permutation(k1, F).astype(jnp.int32),
                     s=jnp.exp(jax.random.normal(k2, (F,)) * 0.4),
                     phi=jnp.zeros((F // 2,)))
    u, d, _, g, _ = apply_transform_ffn(t, wu, wd, None, wg)
    np.testing.assert_allclose(
        np.asarray(_ffn(x, u, d, wg=g, act=jax.nn.silu)),
        np.asarray(_ffn(x, wu, wd, wg=wg, act=jax.nn.silu)),
        rtol=2e-4, atol=2e-4)


def test_combined_psr_composition_order():
    """Eqns 21-22: the combined transform telescopes for identity activation."""
    key = jax.random.PRNGKey(3)
    x, wu, wd, bu, _ = _rand_ffn(key)
    F = wu.shape[1]
    ks = jax.random.split(key, 3)
    t = FFNTransform(pi=jax.random.permutation(ks[0], F).astype(jnp.int32),
                     s=jnp.exp(jax.random.normal(ks[1], (F,)) * 0.3),
                     phi=jax.random.normal(ks[2], (F // 2,)))
    u, d, b, _, _ = apply_transform_ffn(t, wu, wd, bu)
    def ident(v):
        return v
    np.testing.assert_allclose(np.asarray(_ffn(x, u, d, b, act=ident)),
                               np.asarray(_ffn(x, wu, wd, bu, act=ident)),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_proposal_keeps_permutation_valid(seed):
    key = jax.random.PRNGKey(seed)
    t = identity_transform(64)
    pcfg = ProposalConfig()
    for i in range(3):
        key, sub = jax.random.split(key)
        t = propose(sub, t, pcfg)
    pi = np.asarray(t.pi)
    assert sorted(pi.tolist()) == list(range(64)), "pi must stay a permutation"
    assert bool(np.all(np.asarray(t.s) > 0)), "scales must stay positive"


def test_proposal_moves_are_partial():
    """~10% of neurons move per step (the paper's step-size mechanism)."""
    key = jax.random.PRNGKey(0)
    t = propose(key, identity_transform(100), ProposalConfig(subset_frac=0.1))
    moved = int(np.sum(np.asarray(t.pi) != np.arange(100)))
    assert 0 < moved <= 20
    assert int(np.sum(np.asarray(t.s) != 1.0)) <= 20


def test_mamba_within_head_permutation_exact():
    """Beyond-paper: Mamba2 within-head channel permutation is exact
    (DESIGN.md §Arch-applicability)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models.ssm import ssm_forward
    from repro.core.search import MambaAdapter
    from repro.core.invariance import FFNTransform

    cfg = get_config("mamba2-2.7b").reduced(n_layers=1, d_model=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    adapter = MambaAdapter(cfg)
    base = adapter.base_stack(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    p0 = {k: v[0] for k, v in params["blocks"]["ssm"].items()}
    y0 = ssm_forward(p0, cfg, x)

    t = FFNTransform(pi=jnp.arange(adapter.di, dtype=jnp.int32),
                     s=jnp.ones((adapter.di,)), phi=jnp.zeros((adapter.di // 2,)))
    key = jax.random.PRNGKey(2)
    for _ in range(4):
        key, sub = jax.random.split(key)
        t = adapter.propose(sub, t, ProposalConfig(subset_frac=0.5))
    assert int(np.sum(np.asarray(t.pi) != np.arange(adapter.di))) > 0
    unit = adapter.transform_unit(base, t, 0)
    p1 = {**p0, **unit}
    y1 = ssm_forward(p1, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4, atol=2e-4)
