"""Child process for tests/test_ckpt_sharded.py: the multi-device shard
manifest property checks, run under a forced 4-CPU-device topology (the
pytest process itself keeps the real 1-device backend by design — see
tests/conftest.py).

Prints one "OK <check>" line per passing check; any failure raises and the
parent asserts on the exit code + markers.
"""
import os
import sys

os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.ckpt.checkpoint import (CheckpointManager,  # noqa: E402
                                   restore_sharded_checkpoint,
                                   save_sharded_checkpoint)
from repro.core.quant import QTensor, QuantConfig, quantize_tensor  # noqa: E402
from repro.dist.fault import remesh_restore  # noqa: E402
from repro.dist.sharding import ShardingRules, param_specs, to_shardings  # noqa: E402


def main(tmp: str) -> int:
    devs = np.array(jax.devices())
    assert len(devs) == 4, devs
    mesh22 = Mesh(devs.reshape(2, 2), ("data", "model"))
    mesh4 = Mesh(devs, ("data",))
    mesh1 = Mesh(devs[:1], ("data",))

    rng = np.random.default_rng(0)
    w_full = rng.normal(size=(8, 16)).astype(np.float32)
    qt = quantize_tensor(jax.numpy.asarray(
        rng.normal(size=(64, 8)).astype(np.float32)),
        QuantConfig(bits=2, group_size=32))
    qt_full = jax.tree.map(np.asarray, qt)
    tree = {
        "w": jax.device_put(w_full, NamedSharding(mesh22, P("data", "model"))),
        "qt": jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh22, P(None, "model"))),
            qt),
        "nested": {"t": (jax.numpy.arange(4.0), None)},
    }
    d = os.path.join(tmp, "ck")
    save_sharded_checkpoint(d, 3, tree, extra={"note": "prop"})

    def verify(arr, full):
        for s in arr.addressable_shards:
            np.testing.assert_array_equal(np.asarray(s.data), full[s.index])

    # --- restore onto a (4,) mesh, QTensor component specs preserved -------
    sh4 = {
        "w": NamedSharding(mesh4, P("data", None)),
        "qt": QTensor(NamedSharding(mesh4, P(None, "data")),
                      NamedSharding(mesh4, P(None, "data")),
                      NamedSharding(mesh4, P(None, "data")),
                      qt.bits, qt.group_size, qt.shape),
        "nested": {"t": (NamedSharding(mesh4, P("data")), None)},
    }
    r4, m = restore_sharded_checkpoint(d, 3, sh4)
    assert m["format"] == 2 and m["extra"]["note"] == "prop"
    verify(r4["w"], w_full)
    assert r4["w"].sharding.is_equivalent_to(sh4["w"], 2)
    verify(r4["qt"].packed, qt_full.packed)
    verify(r4["qt"].scale, qt_full.scale)
    verify(r4["qt"].zero, qt_full.zero)
    assert r4["qt"].packed.sharding.is_equivalent_to(sh4["qt"].packed, 2)
    assert r4["qt"].bits == qt.bits and r4["qt"].group_size == qt.group_size
    assert r4["qt"].shape == qt.shape
    np.testing.assert_allclose(np.asarray(r4["qt"].dequantize()),
                               np.asarray(qt.dequantize()))
    assert r4["nested"]["t"][1] is None
    print("OK remesh_2x2_to_4")

    # --- restore onto a single-device (1,) mesh ----------------------------
    sh1 = {"w": NamedSharding(mesh1, P()), "qt": None,
           "nested": {"t": (None, None)}}
    r1, _ = restore_sharded_checkpoint(d, 3, sh1)
    np.testing.assert_array_equal(np.asarray(r1["w"]), w_full)
    np.testing.assert_array_equal(np.asarray(r1["qt"].packed), qt_full.packed)
    print("OK remesh_2x2_to_1")

    # --- shardings=None: host-local assembly -------------------------------
    r0, _ = restore_sharded_checkpoint(d, 3, None)
    np.testing.assert_array_equal(np.asarray(r0["w"]), w_full)
    print("OK local_assembly")

    # --- dist.sharding rules round-trip: save under param_specs shardings --
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab_size=128, n_heads=2,
                                         n_kv_heads=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rules = ShardingRules(mesh22, cfg)
    sh = to_shardings(mesh22, param_specs(rules, params))
    params_sh = jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x, params, sh,
        is_leaf=lambda x: x is None)
    mgr = CheckpointManager(os.path.join(tmp, "mgr"), sharded=True)
    mgr.save(7, params_sh)
    mgr.wait()
    rules4 = ShardingRules(mesh4, cfg)
    sh4p = to_shardings(mesh4, param_specs(rules4, params))
    restored, m2 = remesh_restore(mgr, sh4p)
    assert m2["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK manager_param_specs_roundtrip")

    # --- corrupted shard detection -----------------------------------------
    import pathlib
    f = pathlib.Path(d) / "step_00000003" / "host0000.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    try:
        restore_sharded_checkpoint(d, 3, None)
        raise SystemExit("corruption NOT detected")
    except IOError as e:
        assert "host0000.npz" in str(e), e
    print("OK corruption_names_file")

    # --- missing host shard manifest = corruption --------------------------
    (pathlib.Path(d) / "step_00000003" / "shards_host0000.json").unlink()
    try:
        restore_sharded_checkpoint(d, 3, None)
        raise SystemExit("missing shard manifest NOT detected")
    except IOError as e:
        assert "shards_host0000.json" in str(e), e
    print("OK missing_manifest_detected")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
