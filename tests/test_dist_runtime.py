"""Multi-host runtime surface: initialize fallback, psum barrier, device
introspection, elite-state broadcast, mapped-mode guardrails, and the
preemption-signal → checkpoint-and-barrier hook.

These run on the real 1-device backend (tests/conftest.py); the genuinely
multi-device/multi-process behavior is exercised by tests/test_dist_smoke.py
via child processes and by the CI ``distributed`` lane.
"""
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, latest_step
from repro.dist import runtime
from repro.dist.fault import PreemptionGuard, run_resilient


# ---------------- runtime ----------------

def test_initialize_single_process_is_noop(monkeypatch):
    monkeypatch.delenv("REPRO_COORDINATOR", raising=False)
    monkeypatch.delenv("JAX_COORDINATOR_ADDRESS", raising=False)
    assert runtime.initialize() is False
    assert runtime.is_distributed() is False
    assert runtime.process_index() == 0
    assert runtime.process_count() == 1


def test_initialize_rejects_coordinator_without_world_size(monkeypatch):
    """A configured coordinator with no num_processes must raise — silently
    degrading to 0-of-1 on every rank would split-brain the fleet."""
    monkeypatch.delenv("REPRO_NUM_PROCESSES", raising=False)
    with pytest.raises(ValueError, match="num_processes"):
        runtime.initialize(coordinator="127.0.0.1:9999")
    with pytest.raises(ValueError, match="coordinator"):
        runtime.initialize(process_id=1)


def test_device_summary_shape():
    s = runtime.device_summary()
    assert s["process_count"] == 1
    assert s["local_device_count"] == len(jax.local_devices())
    assert s["global_device_count"] == jax.device_count()
    assert s["platform"] == "cpu"


def test_barrier_runs_the_psum_single_process():
    # single-process: same psum code path, degenerate mesh — must not raise
    runtime.barrier("test")
    runtime.barrier("test-again")  # cached compiled fn


def test_global_put_replicated_roundtrip():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()), ("data",))
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    g = runtime.global_put(x, NamedSharding(mesh, P()))
    np.testing.assert_array_equal(np.asarray(g), x)
    t = runtime.replicated({"a": x, "b": None}, mesh)
    np.testing.assert_array_equal(np.asarray(t["a"]), x)


# ---------------- collectives ----------------

def test_elite_broadcast_selects_owner_tree():
    import functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist.collectives import elite_broadcast
    from repro.dist.compat import shard_map

    mesh = Mesh(np.array(jax.devices()), ("data",))

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_vma=False)
    def f(x):
        tree = {"v": x[0], "w": x[0] * 2.0}
        out = elite_broadcast(tree, jnp.int32(0), "data")
        return out["v"], out["w"]

    v, w = f(jnp.asarray([3.0]))
    assert float(v) == 3.0 and float(w) == 6.0


# ---------------- mapped-mode guardrails ----------------

def test_mapped_requires_island_per_device(tiny_cfg):
    """islands != device count must fail fast with an actionable message
    (this pytest process has exactly 1 device by design)."""
    from repro.core.quant import QuantConfig
    from repro.core.search import SearchConfig, run_search
    from repro.models import init_params

    params = init_params(jax.random.PRNGKey(0), tiny_cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                               tiny_cfg.vocab_size)
    scfg = SearchConfig(steps=1, islands=jax.device_count() + 1, mapped=True,
                        n_match_layers=2, log_every=0)
    with pytest.raises(ValueError, match="one island per device"):
        run_search(params, params, tiny_cfg, QuantConfig(bits=2, group_size=32),
                   calib, scfg)


def test_mapped_single_island_single_device(tiny_cfg):
    """The degenerate mapped run (1 island on the 1 local device) must agree
    with sequential bit-for-bit in-process — the n-device version of this
    contract is pinned by tests/test_dist_smoke.py."""
    import dataclasses
    from repro.core.quant import QuantConfig
    from repro.core.search import SearchConfig, run_search
    from repro.models import init_params

    params = init_params(jax.random.PRNGKey(0), tiny_cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                               tiny_cfg.vocab_size)
    scfg = SearchConfig(steps=3, islands=1, n_match_layers=2, log_every=0)
    qcfg = QuantConfig(bits=2, group_size=32)
    r_seq = run_search(params, params, tiny_cfg, qcfg, calib, scfg)
    r_map = run_search(params, params, tiny_cfg, qcfg, calib,
                       dataclasses.replace(scfg, mapped=True))
    assert r_seq.history == r_map.history
    assert r_seq.final_loss == r_map.final_loss
    np.testing.assert_array_equal(np.asarray(r_seq.transforms.pi),
                                  np.asarray(r_map.transforms.pi))


# ---------------- preemption hook ----------------

def test_preemption_guard_drains_to_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path)

    with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
        def step_fn(state, step):
            if step == 3:
                signal.raise_signal(signal.SIGUSR1)  # "eviction notice"
            return {"w": state["w"] + 1}

        state, events = run_resilient(step_fn, {"w": jnp.zeros(())},
                                      n_steps=100, ckpt=mgr, save_every=50,
                                      preemption=guard)
    kinds = [e[0] for e in events]
    assert ("preempted", 4) in events, events
    assert "saved" in kinds
    assert float(state["w"]) == 4.0, "must stop at the next step boundary"
    assert latest_step(tmp_path) == 4, "the drain checkpoint must be durable"
    # the next incarnation resumes exactly where the drain left off
    tree, manifest = mgr.restore()
    assert manifest["step"] == 4 and float(tree["w"]) == 4.0


def test_preemption_guard_restores_previous_handler():
    prev = signal.getsignal(signal.SIGUSR1)
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert signal.getsignal(signal.SIGUSR1) != prev
        assert not g.preempted
    assert signal.getsignal(signal.SIGUSR1) == prev


def test_run_resilient_without_preemption_unchanged(tmp_path):
    """preemption=None keeps the original contract (no early return)."""
    state, events = run_resilient(lambda s, i: {"w": s["w"] + 1},
                                  {"w": jnp.zeros(())}, n_steps=5)
    assert float(state["w"]) == 5.0 and events == []
