"""Import health: every module under ``repro.*`` must import.

A missing module used to cascade into 8 unrelated test errors (the
``repro.dist`` hole reached test_baselines/test_search/test_system through
``launch/train.py``); this smoke test makes the breakage fail in exactly one
obvious place instead.
"""
import importlib
import os
import pkgutil

import jax

import repro


def _all_repro_modules():
    return sorted(m.name for m in pkgutil.walk_packages(repro.__path__,
                                                        prefix="repro."))


def test_walk_finds_the_package_tree():
    names = _all_repro_modules()
    for expected in ("repro.core.quant", "repro.dist.sharding",
                     "repro.dist.fault", "repro.launch.train",
                     "repro.launch.dryrun", "repro.models.model"):
        assert expected in names, f"{expected} missing from package walk"


def test_every_repro_module_imports():
    # Lock the jax backend to the real local devices BEFORE importing
    # launch.dryrun, which writes XLA_FLAGS (a no-op once the backend exists,
    # by design — but only once it exists).
    jax.devices()
    saved_flags = os.environ.get("XLA_FLAGS")
    failures = []
    try:
        for name in _all_repro_modules():
            try:
                importlib.import_module(name)
            except Exception as e:  # noqa: BLE001 — collect every breakage
                failures.append(f"{name}: {type(e).__name__}: {e}")
    finally:
        # dryrun mutates XLA_FLAGS at import; don't leak that to other tests
        if saved_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved_flags
    assert not failures, "unimportable modules:\n  " + "\n  ".join(failures)


def test_dist_package_exports_contract_surface():
    """The API the tests and launchers pin must stay re-exported."""
    import repro.dist as dist
    for name in dist.__all__:
        assert getattr(dist, name, None) is not None, name


def test_serving_package_exports_contract_surface():
    import repro.serving as serving
    for name in serving.__all__:
        assert getattr(serving, name, None) is not None, name


def test_example_serve_quantized_runs():
    """examples/serve_quantized.py must track the serving API: run it (tiny
    args) instead of letting it rot behind the __main__ guard."""
    import importlib.util
    import pathlib
    path = (pathlib.Path(__file__).resolve().parents[1] / "examples"
            / "serve_quantized.py")
    spec = importlib.util.spec_from_file_location("example_serve", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    outs = mod.main(["--requests", "2", "--max-new", "2", "--batch", "2",
                     "--max-len", "32", "--page-size", "8"])
    assert len(outs) == 2 and all(len(o) == 2 for o in outs)
