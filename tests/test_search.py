"""Discrete search (Algorithm 1): loss decreases, state stays valid,
un-quantized invariance is preserved by accepted transforms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig
from repro.core.search import (SearchConfig, run_search, DenseFFNAdapter,
                               MoEAdapter, make_adapter)
from repro.models import forward
from repro.core.objective import calib_ce


@pytest.fixture(scope="module")
def searched(trained_tiny, calib):
    params, cfg = trained_tiny
    qcfg = QuantConfig(bits=2, group_size=32)
    scfg = SearchConfig(steps=120, n_match_layers=2, log_every=0, seed=0)
    res = run_search(params, params, cfg, qcfg, calib, scfg)
    return params, cfg, res


def test_search_monotone_improvement(searched):
    _, _, res = searched
    assert res.final_loss < res.initial_loss, "hill climbing must improve the loss"
    best_curve = []
    best = float("inf")
    for (_, loss, _, _, accepted) in res.history:
        if accepted:
            assert loss < best or best == float("inf")
            best = min(best, loss)
        best_curve.append(best)
    assert best_curve[-1] <= best_curve[1]


def test_search_accept_rate_positive(searched):
    _, _, res = searched
    assert 0.0 < res.accept_rate <= 1.0


def test_search_improves_calibration_ce(searched, calib):
    params, cfg, res = searched
    from repro.core.rtn import rtn_quantize
    qcfg = QuantConfig(bits=2, group_size=32)
    ce_rtn = float(calib_ce(forward(rtn_quantize(params, qcfg), cfg, calib),
                            calib, cfg.vocab_size))
    ce_search = float(calib_ce(forward(res.params_q, cfg, calib), calib,
                               cfg.vocab_size))
    assert ce_search < ce_rtn, (
        f"search ce {ce_search:.4f} must beat plain RTN {ce_rtn:.4f}")


def test_transforms_stay_valid(searched):
    _, cfg, res = searched
    pi = np.asarray(res.transforms.pi)
    for l in range(pi.shape[0]):
        assert sorted(pi[l].tolist()) == list(range(cfg.d_ff))
    assert bool(np.all(np.asarray(res.transforms.s) > 0))


def test_transform_preserves_unquantized_model(searched, calib):
    """Applying the accepted transforms WITHOUT quantization must leave the
    (ReLU) model's outputs unchanged up to tiny-rotation error (Eqn. 6)."""
    params, cfg, res = searched
    adapter = DenseFFNAdapter(cfg)
    base = adapter.base_stack(params)
    units = []
    from repro.core.search import _tree_slice
    import repro.core.invariance as inv
    for u in range(adapter.n_units):
        t = inv.FFNTransform(*_tree_slice(res.transforms, u))
        units.append(adapter.transform_unit(base, t, u))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    params_t = adapter.install(params, stacked)
    l0 = forward(params, cfg, calib)
    l1 = forward(params_t, cfg, calib)
    rel = float(jnp.max(jnp.abs(l1 - l0)) / (jnp.max(jnp.abs(l0)) + 1e-9))
    assert rel < 5e-3, f"invariance violated: rel err {rel:.2e}"


def test_moe_adapter_units():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    adapter = MoEAdapter(cfg)
    assert adapter.n_units == cfg.n_layers * cfg.moe.num_experts
    base = adapter.base_stack(params)
    assert base["up"].shape[0] == adapter.n_units
    # per-expert transform + install round-trips shapes
    import repro.core.invariance as inv
    t = inv.identity_transform(cfg.d_ff)
    unit = adapter.transform_unit(base, t, 3)
    fq = adapter.quant_unit(unit, QuantConfig(bits=2, group_size=32))
    assert fq["up"].shape == (cfg.d_model, cfg.d_ff)


def test_make_adapter_dispatch():
    from repro.configs import get_config
    assert type(make_adapter(get_config("yi-6b"))).__name__ == "DenseFFNAdapter"
    assert type(make_adapter(get_config("phi3.5-moe-42b-a6.6b"))).__name__ == "MoEAdapter"
    assert type(make_adapter(get_config("mamba2-2.7b"))).__name__ == "MambaAdapter"


def test_hybrid_two_phase_search():
    """Zamba2-style hybrid: Mamba within-head perms + shared-FFN P/S/R both
    hill-climb through the composite runner."""
    from repro.configs import get_config
    from repro.core.pipeline import quantize_model
    from repro.models import init_params
    import jax.numpy as jnp

    cfg = get_config("zamba2-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=16)
    scfg = SearchConfig(steps=40, n_match_layers=0, log_every=0)
    r = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib,
                       search=scfg)
    assert r.search.final_loss <= r.search.initial_loss
    assert r.method == "rtn+invarexplore"


def test_mamba_search_end_to_end():
    """Pure-SSM model: permutation-only search must not crash and must not
    regress the calibration loss."""
    from repro.configs import get_config
    from repro.core.pipeline import quantize_model
    from repro.models import init_params

    cfg = get_config("mamba2-2.7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=16)
    scfg = SearchConfig(steps=40, n_match_layers=0, log_every=0)
    r = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib,
                       search=scfg)
    assert r.search.final_loss <= r.search.initial_loss
