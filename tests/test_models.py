"""Model-zoo behaviour: decode consistency, MoE dispatch vs dense reference,
blocked attention vs dense softmax, SSD vs sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, forward, decode_step, prefill
from repro.models.config import ModelConfig, MoEConfig
from repro.models import layers as L


def test_blocked_attention_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, H, Dh = 2, 64, 4, 16
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, Dh))
    out = L.blocked_attention(q, k, v, causal=True, chunk=16)
    # dense reference
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * Dh ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_blocked_attention_gqa_and_kvlen():
    key = jax.random.PRNGKey(3)
    B, Sq, Sk, Hq, Hkv, Dh = 1, 4, 32, 8, 2, 8
    q = jax.random.normal(key, (B, Sq, Hq, Dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, Sk, Hkv, Dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, Sk, Hkv, Dh))
    out_full = L.blocked_attention(q, k, v, causal=False, chunk=8, kv_len=16)
    # zeroing keys beyond kv_len must not change the result
    k2 = k.at[:, 16:].set(99.0)
    v2 = v.at[:, 16:].set(99.0)
    out_masked = L.blocked_attention(q, k2, v2, causal=False, chunk=8, kv_len=16)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_masked), rtol=1e-5)


def _dense_moe_reference(p, cfg, x):
    """Per-token loop reference for MoE routing."""
    B, S, D = x.shape
    logits = x @ p["router"]
    w, idx = jax.lax.top_k(logits, cfg.moe.top_k)
    w = jax.nn.softmax(w, axis=-1)
    act = L.activation_fn(cfg.activation)
    out = jnp.zeros_like(x)
    for e in range(cfg.moe.num_experts):
        up = x @ p["up"][e]
        h = act(x @ p["gate"][e]) * up if "gate" in p else act(up)
        y = h @ p["down"][e]
        for j in range(cfg.moe.top_k):
            out = out + jnp.where((idx[..., j] == e)[..., None], w[..., j:j + 1] * y, 0.0)
    return out


def test_moe_dispatch_matches_dense_reference():
    cfg = ModelConfig(d_model=16, d_ff=32, vocab_size=64,
                      block_pattern="moe", gated_mlp=True,
                      moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    got = L.moe_ffn(p, cfg, x)
    want = _dense_moe_reference(p, cfg, x)
    # capacity_factor=4 => no drops => exact match
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    cfg = ModelConfig(d_model=16, d_ff=32, vocab_size=64, block_pattern="moe",
                      gated_mlp=False,
                      moe=MoEConfig(num_experts=4, top_k=1, capacity_factor=0.5))
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    out = L.moe_ffn(p, cfg, x)  # must run without error; dropped tokens -> 0
    assert out.shape == x.shape and not bool(jnp.any(jnp.isnan(out)))


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == step-by-step h_t = exp(A dt) h + dt B x recurrence."""
    from repro.models.ssm import _ssd_chunked
    key = jax.random.PRNGKey(0)
    B, Lseq, H, P, N = 1, 24, 2, 4, 8
    xh = jax.random.normal(key, (B, Lseq, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (B, Lseq, H))) * 0.3
    Bm = jax.random.normal(jax.random.PRNGKey(2), (B, Lseq, 1, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(3), (B, Lseq, 1, N)) * 0.5
    y, hT = _ssd_chunked(xh, a, Bm, Cm, chunk=8)

    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(Lseq):
        dA = np.exp(np.asarray(a[:, t]))                      # (B,H)
        Bt = np.repeat(np.asarray(Bm[:, t]), H, axis=1)       # (B,H,N)
        Ct = np.repeat(np.asarray(Cm[:, t]), H, axis=1)
        h = h * dA[:, :, None, None] + np.einsum("bhn,bhp->bhpn", Bt, np.asarray(xh[:, t]))
        ys.append(np.einsum("bhn,bhpn->bhp", Ct, h))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-2.7b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(p[:T]) + decode steps == forward(p[:T+k]) logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, T, K = 1, 16, 3
    tokens = jax.random.randint(key, (B, T + K), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens)

    logits, cache = prefill(params, cfg, tokens[:, :T], T + K + 1)
    np.testing.assert_allclose(np.asarray(logits[:, -1]), np.asarray(full[:, T - 1]),
                               rtol=2e-2, atol=2e-2)
    for i in range(K):
        step_logits, cache = decode_step(params, cfg, tokens[:, T + i:T + i + 1],
                                         cache, jnp.int32(T + i))
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full[:, T + i]),
                                   rtol=2e-2, atol=2e-2)


def test_hybrid_layout_counts():
    cfg = get_config("zamba2-7b")
    n_m, n_a = cfg.hybrid_layout()
    assert n_m + n_a == 81 and n_a == 13 and n_m == 68


def test_vocab_padding_masked_in_loss():
    from repro.models import lm_loss
    logits = jnp.zeros((1, 4, 16))
    # huge logits on padded ids must not affect the loss when masked
    logits = logits.at[..., 12:].set(100.0)
    labels = jnp.array([[1, 2, 3, 4]])
    loss_masked = lm_loss(logits, labels, vocab_size=12)
    expect = float(jnp.log(jnp.float32(12.0)))
    assert abs(float(loss_masked) - expect) < 1e-3


def test_flash_decode_integration_matches_blocked_path():
    """cfg.use_flash_decode routes static-position decode through the Pallas
    kernel; output must match the jnp online-softmax path (bf16 and int8)."""
    import dataclasses
    from repro.models import layers as L

    for kv_dtype in ("compute", "int8"):
        cfg = get_config("yi-6b").reduced(n_heads=4, n_kv_heads=2, d_model=64,
                                          head_dim=0)
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype, attn_chunk=32)
        cfg_f = dataclasses.replace(cfg, use_flash_decode=True)
        key = jax.random.PRNGKey(0)
        p = L.init_attn(key, cfg, jnp.float32)
        B, S = 2, 64
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model)) * 0.3
        from repro.models.model import _single_kv
        cache = _single_kv(cfg, B, S, jnp.float32)
        # warm the cache with some prior positions
        for i in range(3):
            xi = jax.random.normal(jax.random.PRNGKey(2 + i), (B, 1, cfg.d_model)) * 0.3
            _, cache = L.self_attention(p, cfg, xi, jnp.array([i]), cache=cache,
                                        cache_index=i)
        out_ref, _ = L.self_attention(p, cfg, x, jnp.array([3]), cache=cache,
                                      cache_index=3)
        out_fl, _ = L.self_attention(p, cfg_f, x, jnp.array([3]), cache=cache,
                                     cache_index=3)
        np.testing.assert_allclose(np.asarray(out_fl), np.asarray(out_ref),
                                   rtol=2e-3, atol=2e-3)
