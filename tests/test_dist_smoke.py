"""The multi-host proof, run locally: drives ``repro.launch.dist_smoke`` as
real child processes — exactly what the CI ``distributed`` lane runs.

- single-process / 2 forced devices: mapped-island search must be bit-for-bit
  equal to the sequential engine, and the sharded checkpoint must round-trip
  through a re-mesh;
- 2 real ``jax.distributed`` processes on one localhost coordinator (2 forced
  devices each → a 4-device global mesh): the same checks, with shards
  written by BOTH processes and cross-process gloo collectives underneath.
"""
import os
import socket
import subprocess
import sys

import pytest

ENV = {**os.environ,
       "JAX_PLATFORMS": "cpu",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
CMD = [sys.executable, "-m", "repro.launch.dist_smoke"]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_dist_smoke_single_process(tmp_path):
    proc = subprocess.run(
        CMD + ["--steps", "3", "--migrate-every", "2",
               "--ckpt-dir", str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"rc={proc.returncode}\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr}")
    assert "mapped parity OK: 2 islands" in proc.stdout
    assert "sharded ckpt OK" in proc.stdout
    assert "DIST_SMOKE_OK process=0/1" in proc.stdout


def test_dist_smoke_two_processes(tmp_path):
    """Real jax.distributed: 2 OS processes, one coordinator, 4 global
    devices, mapped search pinned against the sequential result on both."""
    port = _free_port()
    common = ["--coordinator", f"127.0.0.1:{port}", "--num-processes", "2",
              "--steps", "3", "--migrate-every", "2",
              "--ckpt-dir", str(tmp_path)]
    p1 = subprocess.Popen(CMD + common + ["--process-id", "1"], env=ENV,
                          stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                          text=True)
    try:
        p0 = subprocess.run(CMD + common + ["--process-id", "0"], env=ENV,
                            capture_output=True, text=True, timeout=600)
        out1, _ = p1.communicate(timeout=120)
    except Exception:
        p1.kill()
        raise
    assert p0.returncode == 0, (
        f"proc0 rc={p0.returncode}\n--- stdout ---\n{p0.stdout}\n"
        f"--- stderr ---\n{p0.stderr}\n--- proc1 ---\n{out1}")
    assert p1.returncode == 0, f"proc1 rc={p1.returncode}\n{out1}"
    assert "mapped parity OK: 4 islands" in p0.stdout
    assert "DIST_SMOKE_OK process=0/2" in p0.stdout
    assert "DIST_SMOKE_OK process=1/2" in out1


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
