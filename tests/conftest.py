import os
import sys

# Tests see the REAL device count (1 CPU) — the 512-device override is
# dryrun.py-local by design (assignment spec).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401 — prefer the real package when installed
except ModuleNotFoundError:
    from repro.testing import hypothesis_fallback
    hypothesis_fallback.install()

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_cfg():
    """Small OPT-family config (the paper's model family) for PTQ tests."""
    from repro.configs import get_config
    return get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=4, max_seq_len=256)


@pytest.fixture(scope="session")
def tiny_params(rng_key, tiny_cfg):
    from repro.models import init_params
    return init_params(rng_key, tiny_cfg)


@pytest.fixture(scope="session")
def trained_tiny(tiny_cfg):
    """A tiny OPT actually trained on the synthetic corpus (session-cached) —
    quantization must visibly hurt it, and InvarExplore must visibly help."""
    from repro.launch.train import train
    params, losses, cfg = train(steps=120, batch=8, seq=128, lr=1e-3,
                                reduced=True, cfg=tiny_cfg, log_every=1000)
    assert losses[-1] < losses[0] - 0.5, "training must reduce loss"
    return params, cfg


@pytest.fixture(scope="session")
def calib(tiny_cfg):
    from repro.data.calib import calibration_tokens
    import jax.numpy as jnp
    return jnp.asarray(calibration_tokens(tiny_cfg.vocab_size, n_seqs=4, seq_len=128))
