"""Sharding rules logic (mesh mocked — the real 512-device partitioning is
exercised by launch/dryrun.py, which is itself validated in CI via one cell)."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.dist.sharding import ShardingRules, param_specs, opt_state_specs, cache_specs, data_spec
from repro.launch.steps import param_structs, qparam_structs, input_specs, SHAPES, shape_applicable


def _mock_mesh(shape=((("data", 16), ("model", 16)))):
    m = types.SimpleNamespace()
    m.shape = dict(shape)
    m.axis_names = tuple(k for k, _ in shape)
    return m


def _rules(arch, **kw):
    cfg = get_config(arch)
    return ShardingRules(_mock_mesh(), cfg, **kw), cfg


def _leaves_with_path(tree):
    out = []

    def walk(t, path):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, path + (k,))
        elif isinstance(t, (tuple, list)) and not isinstance(t, P):
            for i, v in enumerate(t):
                walk(v, path + (i,))
        else:
            out.append((path, t))

    walk(tree, ())
    return out


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-4b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-2.7b", "zamba2-7b", "seamless-m4t-medium",
                                  "internvl2-1b", "moonshot-v1-16b-a3b"])
def test_param_specs_rank_and_divisibility(arch):
    rules, cfg = _rules(arch)
    structs = param_structs(cfg)
    specs = param_specs(rules, structs)
    flat_s = dict(_leaves_with_path(specs))
    flat_p = dict(_leaves_with_path(structs))
    assert set(flat_s) == set(flat_p)
    for path, spec in flat_s.items():
        leaf = flat_p[path]
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, f"{path}: spec longer than rank"
        for ax, dim in zip(spec, leaf.shape):
            if ax == "model":
                assert dim % 16 == 0, f"{path}: dim {dim} not divisible by model=16"


def test_internvl2_attention_replicated():
    """14 heads don't divide 16 -> attention weights must replicate."""
    rules, cfg = _rules("internvl2-1b")
    structs = param_structs(cfg)
    specs = param_specs(rules, structs)
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert "model" not in tuple(wq_spec)
    up_spec = specs["blocks"]["mlp"]["up"]
    assert tuple(up_spec)[-1] == "model"  # 4864 % 16 == 0


def test_moe_experts_on_model_axis():
    rules, cfg = _rules("phi3.5-moe-42b-a6.6b")
    specs = param_specs(rules, param_structs(cfg))
    up = specs["blocks"]["moe"]["up"]   # (L, E, D, F)
    assert tuple(up) == (None, "model", None, None)


def test_zero1_shards_a_free_axis():
    rules, cfg = _rules("yi-6b", zero1=True)
    structs = param_structs(cfg)
    ospecs = opt_state_specs(rules, structs)
    m_up = ospecs["m"]["blocks"]["mlp"]["up"]    # (L, D, F): F on model, L or D free
    assert "data" in tuple(m_up)


def test_qtensor_component_specs():
    rules, cfg = _rules("qwen3-4b")
    qstructs = qparam_structs(cfg, QuantConfig(bits=2, group_size=128))
    specs = param_specs(rules, qstructs)
    down = specs["blocks"]["mlp"]["down"]
    # packed K-axis rows: 9728/16=608 % 16 == 0 -> sharded
    assert tuple(down.packed)[-2] == "model"
    # scale rows: 9728/128=76, 76 % 16 != 0 -> replicated fallback
    assert tuple(down.scale)[-2] is None


def test_cache_specs_batch_vs_seq_sharding():
    rules, cfg = _rules("yi-6b")
    # decode_32k: batch 128 divisible by 16 -> batch sharded
    c = cache_specs(rules, cfg, 128)
    assert tuple(c["k"])[1] in ("data", ("data",))
    # long_500k: batch 1 -> sequence sharded over dp
    c1 = cache_specs(rules, cfg, 1)
    assert tuple(c1["k"])[2] in ("data", ("data",))
    assert tuple(c1["k"])[1] is None


def test_data_spec_fallback():
    rules, cfg = _rules("yi-6b")
    first = tuple(data_spec(rules, 256))[0]
    assert first in ("data", ("data",))  # PartitionSpec may normalize 1-tuples
    assert tuple(data_spec(rules, 3))[0] is None  # unshardable batch replicates


def test_shape_applicability_matrix():
    """40 assigned cells; long_500k only for SSM/hybrid (DESIGN.md)."""
    from repro.configs import list_archs
    total, runnable = 0, 0
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            total += 1
            runnable += bool(shape_applicable(cfg, shape))
    assert total == 40
    assert runnable == 32  # 8 full-attention archs skip long_500k


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-2.7b", "seamless-m4t-medium"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_are_structs(arch, shape):
    cfg = get_config(arch)
    kind, structs = input_specs(cfg, shape)
    for leaf in jax.tree.leaves(structs):
        assert isinstance(leaf, (jax.ShapeDtypeStruct,)) or hasattr(leaf, "shape")
