"""Copy-on-write prefix caching + SLO-aware admission (ISSUE 6).

The page-ownership refactor's acceptance bar: pages are refcounted shared
objects (retain/release, free only at zero, double-free guard), matching
full-page prompt runs are aliased from the content-addressed ``PrefixCache``
at admit instead of re-prefilled, identical in-flight requests dedup onto one
page set with decode-time COW forks, outputs stay TOKEN-IDENTICAL to sharing
disabled across ragged prompts / page sizes / GQA, the allocator drains to
all-free after every run (no leaked reference), and the pluggable
``SLOScheduler`` enforces priority admission + per-tenant page quotas +
shared-aware eviction. The >= 8-tenant trace acceptance (>= 50% prefill
tokens saved, bit-identical outputs, no leaks) runs the same
``build_trace`` workload the serving benchmark records.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.models import forward, init_params
from repro.quantized.qmodel import pack_model
from repro.serving import (ContinuousBatcher, PageAllocator, PagedKVCache,
                           PagedRequest, PrefixCache, SLOScheduler,
                           build_trace, chain_keys, make_scheduler)


@pytest.fixture(scope="module")
def packed_tiny():
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pack_model(params, QuantConfig(bits=2, group_size=32))


def _greedy_oracle(params_q, cfg, prompt, n):
    seq = list(prompt)
    out = []
    for _ in range(n):
        logits = forward(params_q, cfg, jnp.asarray([seq], dtype=jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(nxt)
        seq.append(nxt)
    return out


def _drained(cache):
    return cache.allocator.num_free == cache.n_pages - cache.allocator.reserved


# ---------------------------------------------------------------------------
# Refcounted allocator
# ---------------------------------------------------------------------------

def test_refcount_retain_release_semantics():
    a = PageAllocator(n_pages=5)
    ids = a.alloc(2)
    assert all(a.refcount(i) == 1 for i in ids) and a.num_live == 2
    a.retain(ids)
    assert all(a.refcount(i) == 2 for i in ids)
    assert a.release(ids) == [], "first release must not free shared pages"
    assert a.num_free == 2
    freed = a.release(ids)
    assert sorted(freed) == sorted(ids) and a.num_live == 0
    assert a.num_free == 4
    with pytest.raises(ValueError, match="double free"):
        a.release(ids[:1])
    with pytest.raises(ValueError, match="retain of free"):
        a.retain(ids[:1])
    assert a.refcount(ids[0]) == 0


def test_free_is_release_alias():
    """Legacy single-owner callers keep working: ``free`` drops a reference
    and raises on an id freed twice."""
    a = PageAllocator(n_pages=4)
    ids = a.alloc(2)
    a.retain(ids[:1])
    a.free(ids)                       # page 0 survives (cache-style owner)
    assert a.refcount(ids[0]) == 1 and a.refcount(ids[1]) == 0
    with pytest.raises(ValueError):
        a.free(ids[1:])


# ---------------------------------------------------------------------------
# Content addressing + PrefixCache
# ---------------------------------------------------------------------------

def test_chain_keys_commit_to_whole_prefix():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 256, size=21).astype(np.int32)
    keys = chain_keys(toks, 8)
    assert len(keys) == 2, "only FULL pages are addressable"
    same = chain_keys(np.concatenate([toks[:16], toks[:5]]), 8)
    assert same[:2] == keys[:2], "equal token prefix -> equal keys"
    mut = toks.copy()
    mut[3] += 1
    diverged = chain_keys(mut, 8)
    assert diverged[0] != keys[0] and diverged[1] != keys[1], \
        "a page's key must commit to every earlier position (chained hash)"
    mut2 = toks.copy()
    mut2[10] += 1
    d2 = chain_keys(mut2, 8)
    assert d2[0] == keys[0] and d2[1] != keys[1]


def test_prefix_cache_lookup_retains_and_lru_respects_owners():
    a = PageAllocator(n_pages=8)
    pc = PrefixCache(a)
    ids = a.alloc(3)
    keys = [b"k0", b"k1", b"k2"]
    for k, p in zip(keys, ids):
        pc.insert(k, p)
    assert all(a.refcount(p) == 2 for p in ids)   # slot ref + cache ref
    a.release(ids)                                 # producing slot finishes
    run = pc.lookup([keys[0], keys[1], b"missing"])
    assert run == ids[:2], "longest indexed prefix run, in order"
    assert a.refcount(ids[0]) == 2 and a.refcount(ids[2]) == 1
    assert pc.hits == 2 and pc.misses == 1
    # LRU retirement only frees pages the cache exclusively owns: ids[0]/[1]
    # are retained by the lookup caller, so only ids[2] can go
    assert pc.evict_lru(3) == 1
    assert a.refcount(ids[2]) == 0 and len(pc) == 2
    a.release(run)
    pc.clear()
    assert a.num_live == 0 and a.num_free == 7


def test_prefix_cache_reinsert_takes_no_extra_reference():
    a = PageAllocator(n_pages=4)
    pc = PrefixCache(a)
    (pid,) = a.alloc(1)
    assert pc.insert(b"k", pid) is True
    assert pc.insert(b"k", pid) is False, "duplicate key: no second reference"
    assert a.refcount(pid) == 2
    a.release([pid])
    pc.clear()
    assert a.num_live == 0


def test_prefix_cache_max_entries_trims_lru():
    a = PageAllocator(n_pages=8)
    pc = PrefixCache(a, max_entries=2)
    pids = []
    for i in range(3):
        (pid,) = a.alloc(1)
        pc.insert(b"k%d" % i, pid)
        a.release([pid])          # cache becomes the sole owner
        pids.append(pid)
    assert len(pc) == 2, "capacity cap trims the least-recently-used entry"
    assert a.refcount(pids[0]) == 0, "the oldest entry's page went free"
    assert a.num_live == 2
    pc.clear()
    assert a.num_live == 0


# ---------------------------------------------------------------------------
# Sharing on/off equivalence + accounting (the tentpole bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size,n_kv", [(8, 4), (4, 4), (8, 2)])
def test_sharing_on_off_token_identical(page_size, n_kv, packed_tiny):
    """Ragged shared-prefix prompts (including one exact duplicate) through
    the batcher with the prefix cache off and on: every request equals its
    own greedy chain both times, sharing actually happened, and the
    allocator drains to all-free afterwards. Covers MHA + GQA and two page
    sizes."""
    if n_kv == 4:
        cfg, params_q = packed_tiny
    else:
        cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                             vocab_size=256, n_heads=4,
                                             n_kv_heads=n_kv)
        params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                              QuantConfig(bits=2, group_size=32))
    rng = np.random.default_rng(7)
    sys_p = rng.integers(0, cfg.vocab_size, size=2 * page_size).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
             for n in (3, 7, 1, page_size)]
    prompts = [np.concatenate([sys_p, t]) for t in tails]
    prompts.append(prompts[0].copy())      # exact duplicate (dedup path)

    def serve(prefix_cache):
        cache = PagedKVCache(cfg, n_pages=40, page_size=page_size,
                             max_pages_per_seq=8)
        b = ContinuousBatcher(params_q, cfg, cache, max_batch=3,
                              prefill_chunk_pages=2,
                              prefix_cache=prefix_cache)
        outs = b.run([PagedRequest(prompt=p, max_new=4) for p in prompts])
        assert _drained(cache), "leaked page references after run()"
        return outs, b

    outs_off, b_off = serve(False)
    outs_on, b_on = serve(True)
    assert outs_on == outs_off
    assert b_off.stats["prefill_tokens_saved"] == 0
    assert b_on.stats["prefill_tokens_saved"] > 0
    assert b_on.stats["aliased_pages"] > 0
    for p, out in zip(prompts, outs_on):
        assert out == _greedy_oracle(params_q, cfg, p, 4)


def test_prefill_tokens_saved_accounting(packed_tiny):
    """The saved-token ledger is exact: a request sharing k full pages of
    prompt aliases k pages and prefills only its tail."""
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    p2 = np.concatenate([p1[:16],
                         rng.integers(0, cfg.vocab_size, size=5)]).astype(np.int32)
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=6)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefix_cache=True)
    outs = b.run([PagedRequest(prompt=p1, max_new=2),
                  PagedRequest(prompt=p2, max_new=2)])
    assert b.stats["aliased_pages"] == 2          # p2 aliases two full pages
    assert b.stats["prefill_tokens_saved"] == 16
    assert b.stats["prefill_tokens"] == 20 + 5    # p1 whole, p2 tail only
    assert b.stats["dedup_admits"] == 0
    assert outs[0] == _greedy_oracle(params_q, cfg, p1, 2)
    assert outs[1] == _greedy_oracle(params_q, cfg, p2, 2)
    assert _drained(cache)


def test_dedup_twin_shares_pages_and_cow_forks(packed_tiny):
    """Two identical in-flight requests decode from ONE page set: the twin
    admits with zero prefill, and the first decode write into the shared
    tail page copy-on-write forks it — outputs stay exact."""
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(13)
    p = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefix_cache=True)
    outs = b.run([PagedRequest(prompt=p.copy(), max_new=3),
                  PagedRequest(prompt=p.copy(), max_new=3)])
    assert b.stats["dedup_admits"] == 1
    assert b.stats["prefill_tokens_saved"] >= 10  # the twin's whole prompt
    assert b.stats["cow_forks"] >= 1, \
        "both twins write position 10 in the shared page: one must fork"
    want = _greedy_oracle(params_q, cfg, p, 3)
    assert outs[0] == want and outs[1] == want
    assert _drained(cache)


def test_cached_pages_retired_lru_under_pool_pressure(packed_tiny):
    """A full pool retires unreferenced cached runs (LRU) before giving up:
    the second prompt below only fits if the first one's cached pages are
    reclaimed — and it must admit WITHOUT preempting anyone."""
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(17)
    p1 = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=33).astype(np.int32)
    cache = PagedKVCache(cfg, n_pages=6, page_size=8, max_pages_per_seq=5)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefix_cache=True)
    outs = b.run([PagedRequest(prompt=p1, max_new=2),
                  PagedRequest(prompt=p2, max_new=2)])
    assert b.stats["evictions"] == 0, \
        "cache retirement, not preemption, must resolve the pressure"
    assert outs[0] == _greedy_oracle(params_q, cfg, p1, 2)
    assert outs[1] == _greedy_oracle(params_q, cfg, p2, 2)
    assert _drained(cache)


def test_sampled_twins_draw_their_own_streams(packed_tiny):
    """Duplicate-admitted SAMPLING requests share pages + first-token logits
    but sample with their own (seed, index) keys — same content, different
    seeds, independent streams (and COW keeps later writes private)."""
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(19)
    p = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    cache = PagedKVCache(cfg, n_pages=20, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          prefix_cache=True)
    outs = b.run([PagedRequest(prompt=p.copy(), max_new=4, temperature=0.9,
                               seed=s) for s in range(2)])
    assert b.stats["dedup_admits"] == 1
    assert _drained(cache)
    # solo runs with the same seeds are the determinism oracle: page sharing
    # must not perturb either request's sample stream
    for seed, out in enumerate(outs):
        solo_cache = PagedKVCache(cfg, n_pages=20, page_size=8,
                                  max_pages_per_seq=4)
        solo = ContinuousBatcher(params_q, cfg, solo_cache, max_batch=1,
                                 prefix_cache=False)
        assert solo.run([PagedRequest(prompt=p.copy(), max_new=4,
                                      temperature=0.9, seed=seed)])[0] == out


# ---------------------------------------------------------------------------
# SLO scheduler: priority admission, quotas, shared-aware eviction
# ---------------------------------------------------------------------------

def test_slo_priority_admission_order(packed_tiny):
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(23)
    reqs = [PagedRequest(prompt=rng.integers(0, cfg.vocab_size, size=6
                                             ).astype(np.int32),
                         max_new=2, priority=pr) for pr in (0, 2, 1)]
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=1,
                          scheduler=make_scheduler("slo"))
    b.run(reqs)
    assert [r.priority for r in b.done] == [2, 1, 0], \
        "single-slot serving must drain the queue in priority order"


def test_slo_tenant_quota_gates_admission(packed_tiny):
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(29)
    def mk(tenant):
        return PagedRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=10).astype(np.int32),
            max_new=2, tenant=tenant)
    a1, a2, b1 = mk("a"), mk("a"), mk("b")
    cache = PagedKVCache(cfg, n_pages=20, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=3,
                          scheduler=SLOScheduler(tenant_quota=3))
    for r in (a1, a2, b1):
        b.submit(r)
    b._admit()
    live = {id(s.req) for s in b.slots if s is not None}
    assert live == {id(a1), id(b1)}, \
        "tenant a is at quota: its second request must wait, b's admits past"
    while b.queue or any(s is not None for s in b.slots):
        b.step()
    assert len(b.done) == 3 and _drained(cache)


def test_slo_quota_smaller_than_request_stalls_loudly(packed_tiny):
    cfg, params_q = packed_tiny
    cache = PagedKVCache(cfg, n_pages=16, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=2,
                          scheduler=SLOScheduler(tenant_quota=1))
    req = PagedRequest(prompt=np.arange(10, dtype=np.int32), max_new=2)
    with pytest.raises(RuntimeError, match="stalled"):
        b.run([req])


def test_slo_eviction_prefers_low_priority_then_least_progress(packed_tiny):
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(31)
    reqs = [PagedRequest(prompt=rng.integers(0, cfg.vocab_size, size=6
                                             ).astype(np.int32),
                         max_new=4, priority=pr) for pr in (2, 0, 1)]
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=3,
                          scheduler=make_scheduler("slo"))
    for r in reqs:
        b.submit(r)
    b._admit()
    vi = b.scheduler.pick_victim(b)
    assert b.slots[vi].req is reqs[1], "lowest priority is the victim"
    # level the priorities and give reqs[1] extra progress: now the victim
    # is whoever has generated LEAST (cheapest recompute on re-admit)
    reqs[1].priority = reqs[2].priority = reqs[0].priority
    b.slots[vi].req.out.append(0)
    vi2 = b.scheduler.pick_victim(b)
    assert b.slots[vi2].req is not reqs[1]


def test_slo_victim_accounts_for_shared_pages(packed_tiny):
    """Among equal priority/progress, the victim is a slot whose pages are
    SHARED (cheap: a re-admit aliases them right back), not the one holding
    exclusive pages."""
    cfg, params_q = packed_tiny
    rng = np.random.default_rng(37)
    shared = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
    lone = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
    cache = PagedKVCache(cfg, n_pages=24, page_size=8, max_pages_per_seq=4)
    b = ContinuousBatcher(params_q, cfg, cache, max_batch=3,
                          scheduler=make_scheduler("slo"), prefix_cache=True)
    b.submit(PagedRequest(prompt=shared.copy(), max_new=4))
    b.submit(PagedRequest(prompt=shared.copy(), max_new=4))   # dedup twin
    b.submit(PagedRequest(prompt=lone, max_new=4))
    b._admit()
    assert b.stats["dedup_admits"] == 1
    vi = b.scheduler.pick_victim(b)
    assert np.array_equal(b.slots[vi].req.prompt, shared), \
        "the twins own no exclusive page; lone's tail page is exclusive"


# ---------------------------------------------------------------------------
# The >= 8-tenant trace acceptance (same workload the benchmark records)
# ---------------------------------------------------------------------------

def test_many_tenant_trace_sharing_acceptance():
    from repro.launch.serve import PagedServer, Request
    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=64, d_ff=128,
                                         vocab_size=256, n_heads=4,
                                         n_kv_heads=2)
    params_q = pack_model(init_params(jax.random.PRNGKey(0), cfg),
                          QuantConfig(bits=2, group_size=32))
    trace = build_trace(cfg.vocab_size, n_tenants=8, per_tenant=2,
                        page_size=8, max_new=4)
    assert len({t["tenant"] for t in trace}) == 8

    def serve(prefix_cache):
        server = PagedServer(params_q, cfg, max_batch=4, page_size=8,
                             n_pages=64, max_len=64,
                             prefix_cache=prefix_cache)
        outs = server.generate([Request(**t) for t in trace])
        assert _drained(server.cache), "leaked pages on the tenant trace"
        return outs, server

    outs_off, _ = serve(False)
    outs_on, on = serve(True)
    assert outs_on == outs_off, "sharing changed generated tokens"
    rep = on.sharing_report()
    assert rep["saved_frac"] >= 0.5, \
        f"only {rep['saved_frac']:.0%} of prefill tokens aliased"
    assert rep["aliased_pages"] > 0 and rep["prefill_tokens_saved"] > 0
    assert rep["ttft_p50_s"] > 0 and rep["ttft_p99_s"] >= rep["ttft_p50_s"]
