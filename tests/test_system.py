"""End-to-end system test — the paper's central claim on a REAL (trained)
model: 2-bit quantization wrecks perplexity; InvarExplore recovers a
significant part of it ON TOP of the base method (Table 1 behaviour)."""
import jax.numpy as jnp
import pytest

from repro.core.objective import calib_ce
from repro.core.pipeline import quantize_model
from repro.core.quant import QuantConfig
from repro.core.search import SearchConfig
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import forward


@pytest.fixture(scope="module")
def heldout(trained_tiny):
    _, cfg = trained_tiny
    batch_at = make_pipeline(DataConfig(seq_len=128, global_batch=8, seed=4242,
                                        vocab_size=cfg.vocab_size))
    return jnp.asarray(batch_at(0))


def _ppl(params, cfg, tokens):
    return float(jnp.exp(calib_ce(forward(params, cfg, tokens), tokens,
                                  cfg.vocab_size)))


def test_invarexplore_improves_over_rtn(trained_tiny, calib, heldout):
    params, cfg = trained_tiny
    qcfg = QuantConfig(bits=2, group_size=32)

    ppl_fp = _ppl(params, cfg, heldout)
    r_rtn = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib)
    ppl_rtn = _ppl(r_rtn.params_q, cfg, heldout)
    assert ppl_rtn > ppl_fp * 1.05, "2-bit RTN must degrade a trained model"

    scfg = SearchConfig(steps=200, n_match_layers=2, log_every=0)
    r_ie = quantize_model(params, cfg, qcfg, method="rtn", calib_tokens=calib,
                          search=scfg)
    ppl_ie = _ppl(r_ie.params_q, cfg, heldout)
    print(f"\nppl fp={ppl_fp:.2f} rtn={ppl_rtn:.2f} rtn+IE={ppl_ie:.2f}")
    assert ppl_ie < ppl_rtn, (
        f"+InvarExplore ({ppl_ie:.2f}) must beat RTN ({ppl_rtn:.2f}) on HELD-OUT data")
    assert r_ie.search.accept_rate > 0.02


def test_invarexplore_stacks_on_awq(trained_tiny, calib, heldout):
    """The paper's add-on property: AWQ+IE <= AWQ on held-out perplexity."""
    params, cfg = trained_tiny
    qcfg = QuantConfig(bits=2, group_size=32)
    r_awq = quantize_model(params, cfg, qcfg, method="awq", calib_tokens=calib)
    ppl_awq = _ppl(r_awq.params_q, cfg, heldout)
    scfg = SearchConfig(steps=150, n_match_layers=2, log_every=0)
    r_both = quantize_model(params, cfg, qcfg, method="awq", calib_tokens=calib,
                            search=scfg)
    ppl_both = _ppl(r_both.params_q, cfg, heldout)
    print(f"\nppl awq={ppl_awq:.2f} awq+IE={ppl_both:.2f}")
    assert ppl_both < ppl_awq * 1.02, "search must not regress the base method"
