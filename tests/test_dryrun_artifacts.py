"""Dry-run deliverable integrity: every (arch × shape × mesh) cell artifact
exists and PASSED (or is a documented skip). Skips gracefully on a fresh
clone — run ``python -m repro.launch.dryrun --all`` to populate."""
import json
import pathlib

import pytest

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

from repro.configs import list_archs
from repro.launch.steps import SHAPES


pytestmark = pytest.mark.skipif(
    not ART.exists() or not any(ART.glob("*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all)")


def _cells():
    out = {}
    for p in ART.glob("*.json"):
        if "__opt-" in p.name:
            continue
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def test_all_80_cells_present_and_green():
    cells = _cells()
    missing, failed = [], []
    for arch in list_archs():
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = cells.get((arch, shape, mesh))
                if r is None:
                    missing.append((arch, shape, mesh))
                elif not r.get("ok"):
                    failed.append((arch, shape, mesh, r.get("error", "")[:80]))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"
    assert len(cells) == 80


def test_skips_match_design():
    """Exactly the 8 pure-full-attention archs skip long_500k (DESIGN.md)."""
    cells = _cells()
    skipped = sorted({a for (a, s, m), r in cells.items() if r.get("skipped")})
    assert len(skipped) == 8
    assert "zamba2-7b" not in skipped and "mamba2-2.7b" not in skipped


def test_roofline_terms_recorded():
    """Every runnable single-pod cell carries the three roofline terms."""
    cells = _cells()
    for (a, s, m), r in cells.items():
        if m != "16x16" or r.get("skipped"):
            continue
        t = r.get("roofline")
        assert t, f"{a}/{s}: missing roofline terms"
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "useful_ratio", "mfu_bound"):
            assert k in t, f"{a}/{s}: missing {k}"
        assert t["compute_s"] > 0 and t["memory_s"] > 0


def test_hillclimb_variants_exist():
    """§Perf best-variant artifacts for the three selected cells."""
    expected = [
        "moonshot-v1-16b-a3b__train_4k__16x16__opt-remat_dots_all-cap1.json",
        "zamba2-7b__long_500k__16x16__opt-kv_int8.json",
        "yi-6b__decode_32k__16x16__opt-kv_int8-bf16_scores-chunk32k.json",
    ]
    for name in expected:
        p = ART / name
        assert p.exists(), f"missing §Perf artifact {name}"
        r = json.loads(p.read_text())
        assert r["ok"] and r.get("roofline")
