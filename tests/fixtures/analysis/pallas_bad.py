"""Fixture: shapes that cannot use the fused Pallas kernel (3 findings)."""

TQ_SHAPE_PROBES = [
    (4096, 14336, 32, "up"),     # strip blows the _TQ_STRIP_BYTES budget
    (100, 64, 32, "up"),         # K not divisible by group
    (14336, 4000, 32, "down"),   # N has no 128-divisible block
]
