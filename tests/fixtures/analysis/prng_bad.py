"""Fixture: PRNG key reuse + loop carry (2 findings expected)."""
import jax


def bad_reuse(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.normal(key, (4,))   # same key, correlated streams
    return a + b


def bad_loop_carry(key):
    total = 0.0
    for _ in range(4):
        total += jax.random.uniform(key)   # same stream every iteration
    return total
