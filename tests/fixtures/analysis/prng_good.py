"""Fixture: correct key discipline — zero findings expected."""
import jax


def good_split(key):
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, (4,))
    key, sub = jax.random.split(key)
    b = jax.random.normal(sub, (4,))
    return a + b


def good_presplit_loop(key, n):
    ks = jax.random.split(key, n)
    total = 0.0
    for i in range(n):
        total += jax.random.uniform(ks[i])
    return total


def good_fold_in_loop(key):
    total = 0.0
    for step in range(3):
        k = jax.random.fold_in(key, step)
        total += jax.random.uniform(k)
    return total
