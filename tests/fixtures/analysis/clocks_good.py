"""Fixture: monotonic durations + benign wall timestamps — zero findings."""
import time


def good_monotonic():
    t0 = time.monotonic()
    work = sum(range(10))
    return work, time.monotonic() - t0


def good_timestamp():
    started_at = time.time()     # a timestamp, never subtracted: fine
    return {"started_at": started_at, "uptime": time.perf_counter()}
