"""Fixture: shapes that fit the Pallas budget — zero findings expected."""

TQ_SHAPE_PROBES = [
    (2048, 2048, 32, "up"),
    (5504, 2048, 32, "down"),
]
