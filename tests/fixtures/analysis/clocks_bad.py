"""Fixture: wall-clock durations (2 findings expected)."""
import time
from time import time as now


def bad_direct():
    t0 = time.time()
    work = sum(range(10))
    dt = time.time() - t0        # NTP slew makes this negative
    return work, dt


def bad_alias():
    t0 = now()
    return now() - t0            # aliased import resolves too
