"""Fixture: jit-compatible equivalents — zero findings expected."""
import functools

import jax
from jax import lax


@jax.jit
def good_pure(x):
    return x * 2.0


@functools.partial(jax.jit, static_argnames=("mode",))
def good_static_branch(x, mode):
    if mode == "up":          # static argument: host branching is fine
        return x + 1.0
    return x - 1.0


@jax.jit
def good_lax_branch(x):
    return lax.cond(x.sum() > 0, lambda v: v + 1.0, lambda v: v - 1.0, x)


@jax.jit
def good_none_guard(x, bias=None):
    if bias is None:          # `is None` compares are static
        return x
    return x + bias


def good_debug(x):
    jax.debug.print("x = {x}", x=x)
    return x


good_debug_jit = jax.jit(good_debug)
