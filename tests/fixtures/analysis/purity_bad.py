"""Fixture: jit-purity violations, one per flavour (5 findings expected)."""
import time

import jax

STATS = []
COUNT = 0


@jax.jit
def bad_clock(x):
    t0 = time.time()          # trace-time constant
    return x * t0


@jax.jit
def bad_print(x):
    print("tracing", x)       # fires at trace time only
    return x


@jax.jit
def bad_closure(x):
    STATS.append(1)           # once per compile, not per call
    return x


@jax.jit
def bad_global(x):
    global COUNT              # rebinds at trace time
    COUNT = COUNT + 1
    return x


@jax.jit
def bad_branch(x, n):
    if x > 0:                 # Python branch on a traced argument
        return x + n
    return x - n
