"""Fixture: hygienic metric usage — zero findings expected.

Metric names are distinct from metrics_bad.py on purpose: the checker is
project-wide, so shared names would couple the two fixtures.
"""


def install(reg):
    req = reg.counter("fixture_ok_total", "requests")
    req.inc(route="generate")
    req.inc(route="health")
    lat = reg.histogram("fixture_ok_seconds", "request latency")
    lat.observe(0.1, route="generate")
    return req, lat
