"""Fixture: kind clash + mixed label schemas (3 findings expected)."""


def install(reg):
    reg.counter("requests_total", "requests")   # registered as counter...
    reg.gauge("requests_total", "requests")     # ...and as gauge: clash
    lat = reg.histogram("latency_seconds", "request latency")
    lat.observe(0.1, route="generate")
    lat.observe(0.2, route="generate")
    lat.observe(0.3)                            # label schema mismatch
    return lat
