"""Population × island search engine (repro.search): legacy parity,
reproducibility, annealing, migration, and the fused kernel path.

The acceptance bar (ISSUE 3): at ``population=1, islands=1, temperature=0``
the engine must reproduce the legacy single-chain ``run_search`` trajectory
BIT-FOR-BIT on the OPT-paper-family config — ``_legacy_run_search`` below is
a verbatim transcription of the pre-engine loop and the histories are
compared exactly, not approximately.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import invariance as inv
from repro.core import objective as obj
from repro.core.quant import QuantConfig
from repro.core.search import (SearchConfig, run_search, make_adapter,
                               DenseFFNAdapter, _tree_slice, _tree_update)
from repro.models import forward, init_params
from repro.search import anneal
from repro.search.islands import IslandState, make_island_streams, migrate
from repro.search.population import candidate_keys


@pytest.fixture(scope="module")
def tiny_opt():
    cfg = get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=4, max_seq_len=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                               cfg.vocab_size)
    return params, cfg, calib


QCFG = QuantConfig(bits=2, group_size=32)


def _legacy_run_search(params_fp, params_base, cfg, qcfg, calib_tokens, scfg):
    """Verbatim transcription of the pre-engine core/search.py hill climb."""
    adapter = make_adapter(cfg)
    n_match = min(scfg.n_match_layers, cfg.n_layers)
    base = adapter.base_stack(params_base)
    proposer = getattr(adapter, "propose", None) or (
        lambda key, t, pcfg: inv.propose(key, t, pcfg))
    t0 = inv.identity_transform(adapter.f_dim)
    transforms = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (adapter.n_units,) + x.shape).copy(), t0)
    fq_stack = jax.vmap(lambda b: adapter.quant_unit(b, qcfg))(base)
    logits_fp, hidden_fp = forward(params_fp, cfg, calib_tokens,
                                   collect_hidden=True)
    hidden_fp = jax.lax.stop_gradient(hidden_fp[:n_match]) if n_match else None
    logits_fp = jax.lax.stop_gradient(logits_fp)

    @functools.partial(jax.jit, static_argnames=())
    def eval_stack(fq):
        params_q = adapter.install(params_base, fq)
        logits, hidden = forward(params_q, cfg, calib_tokens,
                                 collect_hidden=True)
        if scfg.objective == "kl":
            ce = obj.calib_kl(logits, logits_fp, cfg.vocab_size)
        else:
            ce = obj.calib_ce(logits, calib_tokens, cfg.vocab_size)
        mse = (obj.activation_mse(hidden, hidden_fp, n_match)
               if n_match else jnp.float32(0.0))
        return ce, mse

    ce0, mse0 = map(float, eval_stack(fq_stack))
    alpha = obj.resolve_alpha(ce0, mse0, scfg.ce_weight) if n_match else 0.0
    best = ce0 + alpha * float(mse0)

    @jax.jit
    def step_fn(key, transforms, fq_stack, u):
        k_prop, _ = jax.random.split(key)
        t_u = _tree_slice(transforms, u)
        t_new = proposer(k_prop, inv.FFNTransform(*t_u), scfg.proposal)
        unit = adapter.transform_unit(base, t_new, u)
        unit_fq = adapter.quant_unit(unit, qcfg)
        fq_new = _tree_update(fq_stack, u, unit_fq)
        ce, mse = eval_stack(fq_new)
        loss = ce + alpha * mse
        return loss, ce, mse, fq_new, t_new

    rng = np.random.default_rng(scfg.seed)
    key = jax.random.PRNGKey(scfg.seed)
    history = [(0, best, ce0, float(mse0), True)]
    n_accept = 0
    for step in range(1, scfg.steps + 1):
        key, sub = jax.random.split(key)
        u = jnp.int32(rng.integers(adapter.n_units))
        loss, ce, mse, fq_new, t_new = step_fn(sub, transforms, fq_stack, u)
        loss = float(loss)
        accepted = loss < best
        if accepted:
            best = loss
            fq_stack = fq_new
            transforms = _tree_update(transforms, u, t_new)
            n_accept += 1
        history.append((step, loss, float(ce), float(mse), accepted))
    return history, transforms, best, n_accept


# ---------------------------------------------------------------------------
# Engine-vs-legacy parity (acceptance bar: bit-for-bit)
# ---------------------------------------------------------------------------

def test_engine_reproduces_legacy_bitwise(tiny_opt):
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=40, n_match_layers=2, log_every=0, seed=0)
    assert (scfg.population, scfg.islands, scfg.temperature) == (1, 1, 0.0)
    h_legacy, t_legacy, best_legacy, n_acc = _legacy_run_search(
        params, params, cfg, QCFG, calib, scfg)
    res = run_search(params, params, cfg, QCFG, calib, scfg)
    # exact float equality on every (step, loss, ce, mse, accepted) entry
    assert res.history == h_legacy
    assert np.array_equal(np.asarray(res.transforms.pi), np.asarray(t_legacy.pi))
    assert np.array_equal(np.asarray(res.transforms.s), np.asarray(t_legacy.s))
    assert np.array_equal(np.asarray(res.transforms.phi),
                          np.asarray(t_legacy.phi))
    assert res.final_loss == best_legacy
    assert res.accept_rate == n_acc / scfg.steps


def test_population_batched_eval_improves(tiny_opt):
    """K candidates per step through one vmapped forward: still a valid
    hill climb (loss improves, permutations stay permutations)."""
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=20, n_match_layers=2, log_every=0, population=3)
    res = run_search(params, params, cfg, QCFG, calib, scfg)
    assert res.final_loss < res.initial_loss
    assert res.stats["proposals"] == 20 * 3
    pi = np.asarray(res.transforms.pi)
    for u in range(pi.shape[0]):
        assert sorted(pi[u].tolist()) == list(range(cfg.d_ff))


# ---------------------------------------------------------------------------
# Reproducibility across island counts (satellite contract)
# ---------------------------------------------------------------------------

def test_island0_trajectory_invariant_to_island_count(tiny_opt):
    """Same seed + same population ⇒ island 0's accepted-transform trajectory
    is identical whether it runs alone or beside a second island (migration
    off: elite exchange is the ONLY coupling between islands)."""
    params, cfg, calib = tiny_opt
    s1 = SearchConfig(steps=15, n_match_layers=0, log_every=0, population=2,
                      migrate_every=0)
    s2 = dataclasses.replace(s1, islands=2)
    r1 = run_search(params, params, cfg, QCFG, calib, s1)
    r2 = run_search(params, params, cfg, QCFG, calib, s2)
    assert len(r1.island_histories) == 1 and len(r2.island_histories) == 2
    assert r2.island_histories[0] == r1.island_histories[0]
    # the second island explores a genuinely different stream
    assert r2.island_histories[1] != r2.island_histories[0]


def test_engine_rerun_is_deterministic(tiny_opt):
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=10, n_match_layers=0, log_every=0, population=2,
                        islands=2, migrate_every=4)
    r1 = run_search(params, params, cfg, QCFG, calib, scfg)
    r2 = run_search(params, params, cfg, QCFG, calib, scfg)
    assert r1.island_histories == r2.island_histories
    assert r1.final_loss == r2.final_loss


# ---------------------------------------------------------------------------
# Annealing
# ---------------------------------------------------------------------------

def test_anneal_schedules():
    g = anneal.temperature_schedule("geometric", 2.0, 100)
    assert g(1) < 2.0 and g(100) == pytest.approx(1e-4)
    assert all(g(s) >= g(s + 1) for s in range(1, 100))
    lin = anneal.temperature_schedule("linear", 1.0, 10)
    assert lin(10) == 0.0 and lin(5) == pytest.approx(0.5)
    const = anneal.temperature_schedule("constant", 0.7, 10)
    assert const(9) == 0.7
    zero = anneal.temperature_schedule("geometric", 0.0, 10)
    assert zero(3) == 0.0
    with pytest.raises(ValueError):
        anneal.temperature_schedule("bogus", 1.0, 10)


def test_accept_rule_t0_is_strict_hill_climb():
    assert anneal.accept(-1e-9, 0.0, None)
    assert not anneal.accept(0.0, 0.0, None)
    assert not anneal.accept(1e-9, 0.0, None)
    # Metropolis: uphill accepted iff uniform < exp(-delta/T)
    assert anneal.accept(0.5, 1.0, 0.5)      # exp(-0.5) ~ 0.607
    assert not anneal.accept(0.5, 1.0, 0.7)


def test_annealed_search_takes_uphill_moves_keeps_elite(tiny_opt):
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=20, n_match_layers=0, log_every=0,
                        temperature=10.0, anneal="constant")
    res = run_search(params, params, cfg, QCFG, calib, scfg)
    assert res.stats["uphill_accepts"] >= 1
    accepted = [h[1] for h in res.history if h[4]]
    assert any(b > a for a, b in zip(accepted, accepted[1:])), \
        "a hot chain must move uphill sometimes"
    # elitism: the returned state is the best-ever, never worse than start
    assert res.final_loss <= res.initial_loss
    assert res.final_loss == min(h[1] for h in res.history)


# ---------------------------------------------------------------------------
# Islands: migration + streams
# ---------------------------------------------------------------------------

def _mk_island(i, cur, best):
    rng, key = make_island_streams(0, i)
    return IslandState(index=i, rng=rng, key=key, transforms=f"t{i}",
                       fq_stack=f"fq{i}", current_loss=cur, best_loss=best,
                       best_transforms=f"bt{i}", best_fq=f"bfq{i}")


def test_migrate_moves_elite_to_worst():
    a = _mk_island(0, cur=1.0, best=0.5)
    b = _mk_island(1, cur=3.0, best=2.0)
    assert migrate([a, b])
    assert b.current_loss == 0.5 and b.fq_stack == "bfq0"
    assert b.best_loss == 0.5 and b.best_transforms == "bt0"
    # donor untouched
    assert a.current_loss == 1.0 and a.best_loss == 0.5


def test_migrate_noop_cases():
    assert not migrate([_mk_island(0, 1.0, 0.5)])           # single island
    # the elite island is ITSELF the worst-current chain: nothing to move
    a, b = _mk_island(0, 2.0, 0.1), _mk_island(1, 1.0, 0.5)
    assert not migrate([a, b])
    assert a.fq_stack == "fq0" and b.fq_stack == "fq1"


def test_island_streams_island0_is_legacy():
    rng0, key0 = make_island_streams(7, 0)
    assert rng0.integers(1 << 30) == np.random.default_rng(7).integers(1 << 30)
    assert np.array_equal(np.asarray(key0),
                          np.asarray(jax.random.PRNGKey(7)))
    rng1, key1 = make_island_streams(7, 1)
    assert not np.array_equal(np.asarray(key0), np.asarray(key1))


def test_candidate_keys_k1_matches_legacy_split():
    sub = jax.random.PRNGKey(123)
    legacy_k_prop, _ = jax.random.split(sub)
    assert np.array_equal(np.asarray(candidate_keys(sub, 1)[0]),
                          np.asarray(legacy_k_prop))


def test_elite_over_mesh_local():
    """Elite selection through the dist collective on the local mesh."""
    from repro.dist.compat import shard_map
    from repro.launch.mesh import make_local_mesh
    from repro.search.islands import elite_over_mesh
    import jax.sharding as shd
    mesh = make_local_mesh()
    n = len(jax.devices())
    losses = jnp.arange(n, 0, -1).astype(jnp.float32)  # min on the last shard
    f = shard_map(lambda x: elite_over_mesh(x[0], "data"),
                  mesh=mesh, in_specs=shd.PartitionSpec("data"),
                  out_specs=(shd.PartitionSpec(), shd.PartitionSpec()),
                  check_vma=False)
    best, idx = f(losses)
    assert float(best) == 1.0 and int(idx) == n - 1


# ---------------------------------------------------------------------------
# Fused transform+fake-quant path
# ---------------------------------------------------------------------------

def test_fused_adapter_unit_matches_unfused(tiny_opt):
    params, cfg, calib = tiny_opt
    adapter = DenseFFNAdapter(cfg)
    base = adapter.base_stack(params)
    key = jax.random.PRNGKey(5)
    t = inv.propose(key, inv.identity_transform(cfg.d_ff),
                    inv.ProposalConfig())
    want = adapter.quant_unit(adapter.transform_unit(base, t, 1), QCFG)
    got = adapter.transform_quant_unit(base, t, 1, QCFG)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-5)


def test_fused_engine_run_improves(tiny_opt):
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=10, n_match_layers=0, log_every=0, population=2,
                        fused_kernel=True)
    res = run_search(params, params, cfg, QCFG, calib, scfg)
    assert res.final_loss < res.initial_loss
    assert res.stats["fused"] is True


def test_fused_downgrade_warns_and_is_recorded():
    """Regression (ISSUE 4): an adapter without ``transform_quant_unit``
    (MambaAdapter) must WARN when fused_kernel=True is silently unusable,
    and record stats["fused"] = False instead of dropping the request."""
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                               cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=16)
    scfg = SearchConfig(steps=2, n_match_layers=0, log_every=0,
                        fused_kernel=True)
    with pytest.warns(UserWarning, match="transform_quant_unit"):
        res = run_search(params, params, cfg, qcfg, calib, scfg)
    assert res.stats["fused"] is False


def test_fused_bias_and_gate_transform_ordering():
    """Regression (ISSUE 4): ``DenseFFNAdapter.transform_quant_unit`` must
    transform b_up as (rotate -> scale -> permute) and b_gate as
    permute-only — EXACTLY ``inv.apply_transform_ffn``'s ordering — on a
    gated + biased FFN (the seed cfgs exercise bias xor gate, never both)."""
    cfg = get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=4, gated_mlp=True, use_bias=True)
    params = init_params(jax.random.PRNGKey(3), cfg)
    adapter = DenseFFNAdapter(cfg)
    base = adapter.base_stack(params)
    assert set(base) >= {"up", "down", "gate", "b_up", "b_gate"}
    t = inv.propose(jax.random.PRNGKey(9), inv.identity_transform(cfg.d_ff),
                    inv.ProposalConfig())
    got = adapter.transform_quant_unit(base, t, 0, QCFG)
    b = jax.tree.map(lambda x: x[0], base)
    _, _, b_up_ref, _, b_gate_ref = inv.apply_transform_ffn(
        t, b["up"], b["down"], b["b_up"], b["gate"], b["b_gate"])
    np.testing.assert_allclose(np.asarray(got["b_up"]),
                               np.asarray(b_up_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got["b_gate"]),
                               np.asarray(b_gate_ref), rtol=0, atol=0)
    # and the fused weights still agree with the unfused composition
    want = adapter.quant_unit(adapter.transform_unit(base, t, 0), QCFG)
    for k in ("up", "gate", "down"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# Stats correctness (ISSUE 4 satellites)
# ---------------------------------------------------------------------------

def test_uphill_accepts_counts_strict_uphill_as_int(tiny_opt):
    """``uphill_accepts`` must count accepted moves with delta STRICTLY > 0
    (delta == 0 is lateral) and be a Python int, never a numpy bool sum.
    Pinned by recomputing the count from the engine's own history."""
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=20, n_match_layers=0, log_every=0,
                        temperature=10.0, anneal="constant")
    res = run_search(params, params, cfg, QCFG, calib, scfg)
    assert type(res.stats["uphill_accepts"]) is int
    cur = res.history[0][1]
    strict_uphill = 0
    for _, loss, _, _, accepted in res.history[1:]:
        if accepted:
            strict_uphill += loss > cur
            cur = loss
    assert res.stats["uphill_accepts"] == strict_uphill
    # and a cold chain can never move uphill
    cold = run_search(params, params, cfg, QCFG, calib,
                      SearchConfig(steps=10, n_match_layers=0, log_every=0))
    assert cold.stats["uphill_accepts"] == 0


# ---------------------------------------------------------------------------
# The one front door (ISSUE 10 satellite: repro.search.run)
# ---------------------------------------------------------------------------

def test_front_door_matches_legacy_bitwise(tiny_opt):
    """``repro.search.run`` at the default config reproduces the legacy
    trajectory bit-for-bit, and the deprecated ``run_search`` shim returns
    the identical result under a DeprecationWarning."""
    import repro.search as search
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=12, n_match_layers=2, log_every=0, seed=0)
    h_legacy, t_legacy, best_legacy, n_acc = _legacy_run_search(
        params, params, cfg, QCFG, calib, scfg)
    res = search.run(params, params, cfg, QCFG, calib, scfg)
    assert res.history == h_legacy
    assert res.final_loss == best_legacy
    assert np.array_equal(np.asarray(res.transforms.pi),
                          np.asarray(t_legacy.pi))
    assert res.stats["objective"] == "ce"
    assert res.stats["install"] == "unit"
    with pytest.warns(DeprecationWarning, match="run_search is deprecated"):
        res_shim = run_search(params, params, cfg, QCFG, calib, scfg)
    assert res_shim.history == res.history
    assert res_shim.final_loss == res.final_loss


def test_front_door_objective_kwarg_overrides_config(tiny_opt):
    """``run(..., objective=...)`` wins over ``SearchConfig.objective`` and
    is recorded in the result stats."""
    import repro.search as search
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=3, n_match_layers=0, log_every=0,
                        objective="ce")
    res = search.run(params, params, cfg, QCFG, calib, scfg, objective="kl")
    assert res.stats["objective"] == "kl"
    assert all(np.isfinite(h[1]) for h in res.history)


def test_front_door_auto_dispatches_hybrid():
    """A hybrid block pattern routes through the two-phase composite with no
    explicit runner choice (the legacy run_search_hybrid semantics)."""
    import repro.search as search
    cfg = get_config("zamba2-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                               cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=16)
    res = search.run(params, params, cfg, qcfg, calib,
                     SearchConfig(steps=5, n_match_layers=0, log_every=0))
    # two phases: (2 steps + step-0) + (3 steps + step-0)
    assert len(res.history) == 5 + 2
    assert res.stats["proposals"] == 5


def test_run_population_search_shim_warns(tiny_opt):
    params, cfg, calib = tiny_opt
    from repro.core.search import make_adapter
    from repro.search.engine import run_population_search
    scfg = SearchConfig(steps=2, n_match_layers=0, log_every=0)
    with pytest.warns(DeprecationWarning, match="run_population_search"):
        res = run_population_search(params, params, cfg, QCFG, calib, scfg,
                                    adapter=make_adapter(cfg))
    assert res.final_loss <= res.initial_loss


def test_hybrid_search_spends_odd_step_budgets_fully():
    """Regression (ISSUE 4): ``run_search_hybrid`` with ODD steps must run
    ``steps // 2`` + ``steps - steps // 2`` (not halve twice), and merge
    histories/stats across both phases."""
    from repro.core.search import run_search_hybrid
    cfg = get_config("zamba2-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                               cfg.vocab_size)
    qcfg = QuantConfig(bits=2, group_size=16)
    res = run_search_hybrid(params, params, cfg, qcfg, calib,
                            SearchConfig(steps=7, n_match_layers=0,
                                         log_every=0))
    # two phases, each history = steps + 1 (the step-0 entry): 3+1 + 4+1
    assert len(res.history) == 7 + 2
    assert res.stats["proposals"] == 7, "odd budgets must be spent in full"
    assert len(res.island_histories) == 1
    assert len(res.island_histories[0]) == 7 + 2
    assert type(res.stats["uphill_accepts"]) is int
