"""Search framework v2 (ISSUE 10): O(unit)-memory candidate install,
pluggable objectives, tried-point tabu memory, sharded per-island
calibration.

Property bars:
  * dynamic-slice install == full-stack install — exact on Dense AND MoE
    unit stacks, and through the engine (bit-for-bit at K=1 where both
    modes route the legacy single-jit step; <= 1e-5 at K>1);
  * objective registry round-trips strings and instances;
  * sharded-vs-replicated calibration is bitwise identical at 1 island;
  * the tabu memory never blocks an improving move and never perturbs the
    trajectory (hit replay is exact; no extra PRNG per skip).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import invariance as inv
from repro.core import objective as obj
from repro.core.quant import QuantConfig
from repro.core.search import (DenseFFNAdapter, MoEAdapter, SearchConfig,
                               _tree_update)
from repro.models import init_params
from repro.search import run as search_run
from repro.search.install import (eval_candidates_stack, eval_candidates_unit,
                                  stack_unit_batch, tree_bytes,
                                  tree_install_unit)
from repro.search.tabu import TabuMemory, transform_bytes

QCFG = QuantConfig(bits=2, group_size=32)


@pytest.fixture(scope="module")
def tiny_opt():
    cfg = get_config("opt-tiny").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
        n_kv_heads=4, max_seq_len=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                               cfg.vocab_size)
    return params, cfg, calib


# ---------------------------------------------------------------------------
# install: dynamic-slice surgery == indexed-update surgery
# ---------------------------------------------------------------------------

def _install_equiv_on(adapter, params):
    base = adapter.base_stack(params)
    fq = jax.vmap(lambda b: adapter.quant_unit(b, QCFG))(base)
    rng = np.random.default_rng(0)
    for u in (0, adapter.n_units - 1, int(rng.integers(adapter.n_units))):
        unit = jax.tree.map(
            lambda x: x[u] + jnp.asarray(rng.normal(), x.dtype), fq)
        via_slice = tree_install_unit(fq, jnp.int32(u), unit)
        via_index = _tree_update(fq, u, unit)
        for a, b in zip(jax.tree.leaves(via_slice),
                        jax.tree.leaves(via_index)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # untouched units really are untouched
        for a, b in zip(jax.tree.leaves(via_slice), jax.tree.leaves(fq)):
            mask = np.ones(a.shape[0], bool)
            mask[u] = False
            np.testing.assert_array_equal(np.asarray(a)[mask],
                                          np.asarray(b)[mask])


def test_install_unit_equals_tree_update_dense(tiny_opt):
    params, cfg, _ = tiny_opt
    _install_equiv_on(DenseFFNAdapter(cfg), params)


def test_install_unit_equals_tree_update_moe():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    adapter = MoEAdapter(cfg)
    assert adapter.n_units == cfg.n_layers * cfg.moe.num_experts
    _install_equiv_on(adapter, params)


def test_eval_candidates_unit_matches_stack(tiny_opt):
    """The two install lanes score identical candidates identically (the
    eval here is a cheap deterministic reduction, so equality is exact)."""
    params, cfg, _ = tiny_opt
    adapter = DenseFFNAdapter(cfg)
    base = adapter.base_stack(params)
    fq = jax.vmap(lambda b: adapter.quant_unit(b, QCFG))(base)
    K, u = 3, 1
    units = [jax.tree.map(lambda x: x[u] * (1.0 + 0.1 * i), fq)
             for i in range(K)]
    batch = stack_unit_batch(units)

    def eval_fn(stack):
        flat = sum(jnp.sum(x) for x in jax.tree.leaves(stack))
        return flat, flat * 0.5

    p_u, a_u = jax.jit(
        lambda b: eval_candidates_unit(b, fq, u, eval_fn))(batch)
    p_s, a_s = jax.jit(
        lambda b: eval_candidates_stack(b, fq, u, eval_fn))(batch)
    assert p_u.shape == (K,)
    np.testing.assert_allclose(np.asarray(p_u), np.asarray(p_s), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a_u), np.asarray(a_s), rtol=1e-6)
    # the candidate buffer really is K x unit, not K x stack
    assert tree_bytes(batch) * adapter.n_units == tree_bytes(fq) * K


def test_engine_k1_install_modes_bitwise(tiny_opt):
    """K=1 routes BOTH install modes through the legacy single-jit step:
    trajectories are bit-for-bit identical by construction."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=8, n_match_layers=2, log_every=0)
    r_u = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="unit"))
    r_s = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="stack"))
    assert r_u.history == r_s.history
    assert r_u.final_loss == r_s.final_loss


def test_engine_k3_install_modes_close(tiny_opt):
    """K>1: unit-install (lax.map over per-unit buffers) and stack-install
    (vmap over K stacks) run different XLA programs over the same math —
    same accept decisions, losses within 1e-5."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=8, n_match_layers=2, log_every=0, population=3)
    r_u = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="unit"))
    r_s = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="stack"))
    assert r_u.stats["install"] == "unit"
    assert r_s.stats["install"] == "stack"
    assert len(r_u.history) == len(r_s.history)
    for hu, hs in zip(r_u.history, r_s.history):
        assert hu[0] == hs[0] and hu[4] == hs[4]   # step, accepted
        np.testing.assert_allclose(hu[1:4], hs[1:4], rtol=0, atol=1e-5)


def test_engine_rejects_unknown_install(tiny_opt):
    params, cfg, calib = tiny_opt
    with pytest.raises(ValueError, match="install"):
        search_run(params, params, cfg, QCFG, calib,
                   SearchConfig(steps=1, log_every=0, install="bogus"))


def test_measure_memory_unit_batch_smaller_than_stack(tiny_opt):
    """``measure_memory=True`` reports the memory model: the candidate
    buffer is K x unit under install='unit' vs K x stack under 'stack'."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=4, n_match_layers=0, log_every=0, population=4,
                     measure_memory=True)
    r_u = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="unit"))
    r_s = search_run(params, params, cfg, QCFG, calib,
                     dataclasses.replace(s, install="stack"))
    for r in (r_u, r_s):
        assert {"peak_live_bytes", "stack_bytes",
                "candidate_batch_bytes"} <= set(r.stats)
    assert r_u.stats["stack_bytes"] == r_s.stats["stack_bytes"]
    # K x unit  vs  K x stack: smaller by exactly the unit count
    n_units = DenseFFNAdapter(cfg).n_units
    assert (r_u.stats["candidate_batch_bytes"] * n_units
            == r_s.stats["candidate_batch_bytes"])


# ---------------------------------------------------------------------------
# objective registry
# ---------------------------------------------------------------------------

def test_objective_registry_round_trip():
    for name, cls in (("ce", obj.CEObjective), ("kl", obj.KLObjective),
                      ("swd_actmatch", obj.SWDActMatchObjective),
                      ("saliency_ce", obj.SaliencyCEObjective)):
        got = obj.get_objective(name)
        assert isinstance(got, cls) and got.name == name
        assert obj.objective_name(name) == name
        # instance pass-through: the SAME object comes back
        assert obj.get_objective(got) is got
        assert obj.objective_name(got) == name
    assert isinstance(obj.get_objective(None), obj.CEObjective)


def test_objective_registry_errors_and_register():
    with pytest.raises(ValueError, match="swd_actmatch"):
        obj.get_objective("nope")
    with pytest.raises(TypeError):
        obj.get_objective(42)
    with pytest.raises(ValueError, match="already registered"):
        obj.register_objective("ce", obj.CEObjective)

    class Custom(obj.Objective):
        name = "custom_t10"

    obj.register_objective("custom_t10", Custom)
    try:
        assert isinstance(obj.get_objective("custom_t10"), Custom)
        obj.register_objective("custom_t10", Custom, overwrite=True)
    finally:
        obj.OBJECTIVES.pop("custom_t10", None)


def test_objective_instance_through_config(tiny_opt):
    """SearchConfig.objective accepts an Objective INSTANCE, not only a
    registry name."""
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=3, n_match_layers=2, log_every=0,
                        objective=obj.KLObjective())
    res = search_run(params, params, cfg, QCFG, calib, scfg)
    assert res.stats["objective"] == "kl"
    assert res.final_loss <= res.initial_loss


@pytest.mark.parametrize("name", ["swd_actmatch", "saliency_ce"])
def test_new_objectives_run_end_to_end(tiny_opt, name):
    params, cfg, calib = tiny_opt
    scfg = SearchConfig(steps=6, n_match_layers=2, log_every=0,
                        objective=name, population=2)
    res = search_run(params, params, cfg, QCFG, calib, scfg)
    assert res.stats["objective"] == name
    assert np.isfinite(res.initial_loss) and np.isfinite(res.final_loss)
    assert res.final_loss <= res.initial_loss      # elitism
    assert all(np.isfinite(h[1]) for h in res.history)


def test_swd_is_permutation_invariant_and_discriminative(tiny_opt):
    """SWD over activation clouds: zero against itself under sample
    permutation, positive against a shifted cloud."""
    params, cfg, calib = tiny_opt
    swd = obj.SWDActMatchObjective(n_proj=16)
    env = obj.ObjectiveEnv(calib=calib, logits_fp=jnp.zeros((2, 4, 8)),
                           hidden_fp=jax.random.normal(
                               jax.random.PRNGKey(0), (2, 2, 16, 8)),
                           vocab_size=8, n_match=2)
    state = swd.prepare(env)
    x = env.hidden_fp.astype(jnp.float32).reshape(2, -1, 8)
    perm = jax.random.permutation(jax.random.PRNGKey(1), x.shape[1])

    def dist(cloud):
        proj = cloud @ state["dirs"]
        return float(jax.vmap(obj._swd_1d)(state["ref_sorted"], proj).mean())

    assert dist(x[:, perm]) == pytest.approx(0.0, abs=1e-9)
    assert dist(x + 3.0) > 1e-2


def test_saliency_weights_are_fp_confidence(tiny_opt):
    """saliency_ce weights = FP model's probability of the true next token,
    normalized to mean 1 — confident positions dominate the objective."""
    params, cfg, calib = tiny_opt
    from repro.models import forward
    logits_fp, hidden = forward(params, cfg, calib, collect_hidden=True)
    env = obj.ObjectiveEnv(calib=calib, logits_fp=logits_fp,
                           hidden_fp=hidden[:2], vocab_size=cfg.vocab_size,
                           n_match=2)
    sal = obj.SaliencyCEObjective()
    w = np.asarray(sal.prepare(env)["w"])
    assert w.shape == (calib.shape[0], calib.shape[1] - 1)
    assert np.all(w >= 0)
    assert np.mean(w) == pytest.approx(1.0, rel=1e-5)
    # evaluating the FP model itself reproduces a weighted CE, not garbage
    p, a = sal.evaluate(logits_fp, hidden, sal.prepare(env), env)
    assert np.isfinite(float(p)) and float(p) > 0


# ---------------------------------------------------------------------------
# sharded per-island calibration
# ---------------------------------------------------------------------------

def test_shard_calibration_slices():
    from repro.data.calib import shard_calibration
    calib = np.arange(24).reshape(6, 4)
    parts = shard_calibration(calib, 3)
    assert [p.shape for p in parts] == [(2, 4)] * 3
    np.testing.assert_array_equal(np.concatenate(parts), calib)
    assert shard_calibration(calib, 1)[0] is calib
    with pytest.raises(ValueError, match="divide"):
        shard_calibration(calib, 4)


def test_sharded_calib_one_island_is_bitwise_replicated(tiny_opt):
    """1 island => the shard IS the full batch: the sharded lane must
    reproduce the replicated lane exactly, per-entry."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=8, n_match_layers=2, log_every=0, population=2)
    r_rep = search_run(params, params, cfg, QCFG, calib, s)
    r_shd = search_run(params, params, cfg, QCFG, calib,
                       dataclasses.replace(s, shard_calib=True))
    assert r_shd.stats["shard_calib"] is True
    assert r_shd.history == r_rep.history
    assert r_shd.final_loss == r_rep.final_loss


def test_sharded_calib_islands_climb_their_own_slices(tiny_opt):
    """2 islands x 1-seq slices: both chains improve on their OWN data and
    migration still exchanges elites on the scalar estimates."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=10, n_match_layers=2, log_every=0, islands=2,
                     migrate_every=4, shard_calib=True)
    res = search_run(params, params, cfg, QCFG, calib, s)
    assert len(res.island_histories) == 2
    # per-slice baselines differ (different data!), and each history starts
    # at its own island's step-0 loss
    l0 = [h[0][1] for h in res.island_histories]
    assert l0[0] != l0[1]
    assert res.final_loss <= min(h0 for h0 in l0)
    assert res.initial_loss in l0


# ---------------------------------------------------------------------------
# tabu memory
# ---------------------------------------------------------------------------

def test_tabu_memory_unit():
    t = inv.identity_transform(8)
    b = transform_bytes(t)
    mem = TabuMemory(capacity=2)
    fp = mem.fingerprint(3, b)
    assert mem.lookup(fp) is None and mem.hits == 0
    mem.record(fp, 1.5, 1.0, 0.5)
    assert mem.lookup(fp) == (1.5, 1.0, 0.5) and mem.hits == 1
    # the digest advance invalidates every pre-accept fingerprint
    mem.advance(b)
    assert mem.fingerprint(3, b) != fp
    assert mem.lookup(mem.fingerprint(3, b)) is None
    # LRU capacity bound
    for i in range(4):
        mem.record(mem.fingerprint(i, b), float(i), 0.0, 0.0)
    assert len(mem) == 2
    # migration adoption re-keys the digest off the donor
    other = TabuMemory()
    other.advance(b)
    before = mem.fingerprint(0, b)
    mem.adopt_digest(other)
    assert mem.fingerprint(0, b) != before


class _ConstProposalAdapter(DenseFFNAdapter):
    """Proposal depends only on (state, unit) — every re-visit of an
    unaccepted state re-proposes the SAME point, forcing tabu hits."""

    def propose(self, key, t, pcfg):
        del key
        return inv.propose(jax.random.PRNGKey(7), t, pcfg)


def test_tabu_hits_do_not_perturb_the_trajectory(tiny_opt):
    """K=2 routes tabu=0 and tabu>0 through the SAME staged programs, so
    with a state-deterministic proposer the tabu run must (a) take hits,
    (b) replay them exactly — bit-identical histories — and (c) never block
    an improving move (the accepted-move set is identical)."""
    params, cfg, calib = tiny_opt
    adapter = _ConstProposalAdapter(cfg)
    s = SearchConfig(steps=12, n_match_layers=0, log_every=0, population=2)
    r_plain = search_run(params, params, cfg, QCFG, calib, s,
                         adapter=adapter)
    r_tabu = search_run(params, params, cfg, QCFG, calib,
                        dataclasses.replace(s, tabu=64), adapter=adapter)
    assert r_tabu.stats["tabu_hits"] > 0
    assert r_tabu.history == r_plain.history
    assert r_tabu.final_loss == r_plain.final_loss
    assert np.array_equal(np.asarray(r_tabu.transforms.pi),
                          np.asarray(r_plain.transforms.pi))


def test_tabu_with_random_proposals_is_transparent(tiny_opt):
    """With the real key-driven proposer, collisions are vanishingly rare:
    the tabu machinery must be a bit-exact no-op on the trajectory."""
    params, cfg, calib = tiny_opt
    s = SearchConfig(steps=6, n_match_layers=0, log_every=0, population=2)
    r_plain = search_run(params, params, cfg, QCFG, calib, s)
    r_tabu = search_run(params, params, cfg, QCFG, calib,
                        dataclasses.replace(s, tabu=64))
    assert r_tabu.history == r_plain.history
    assert r_tabu.stats["tabu_hits"] == 0


def test_tabu_annealed_accept_from_cache(tiny_opt):
    """T>0 with a state-deterministic proposer: cached (previously
    rejected) moves can be re-drawn and ACCEPTED by the Metropolis rule —
    the rebuild-from-cache path must produce a consistent run, and the
    PRNG/uniform streams stay aligned (rerun determinism)."""
    params, cfg, calib = tiny_opt
    adapter = _ConstProposalAdapter(cfg)
    s = SearchConfig(steps=15, n_match_layers=0, log_every=0, population=2,
                     temperature=5.0, anneal="constant", tabu=64)
    r1 = search_run(params, params, cfg, QCFG, calib, s, adapter=adapter)
    r2 = search_run(params, params, cfg, QCFG, calib, s, adapter=adapter)
    assert r1.history == r2.history
    assert r1.stats["tabu_hits"] == r2.stats["tabu_hits"]
    assert r1.final_loss <= r1.initial_loss        # elitism survives
    pi = np.asarray(r1.transforms.pi)
    for u in range(pi.shape[0]):                   # still permutations
        assert sorted(pi[u].tolist()) == list(range(cfg.d_ff))


def test_tabu_rejects_mapped(tiny_opt):
    params, cfg, calib = tiny_opt
    with pytest.raises(ValueError, match="tabu"):
        search_run(params, params, cfg, QCFG, calib,
                   SearchConfig(steps=1, log_every=0, tabu=8, mapped=True))
