"""Substrate: data determinism, AdamW, checkpointing, fault tolerance,
compressed collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, make_pipeline, SyntheticZipf
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule)
from repro.ckpt.checkpoint import (CheckpointManager, save_checkpoint,
                                   restore_checkpoint)
from repro.dist.fault import StepWatchdog, run_resilient
from repro.core.quant import QuantConfig, quantize_tensor


# ---------------- data ----------------

def test_pipeline_deterministic_across_restart():
    cfg = DataConfig(seq_len=32, global_batch=4, seed=5)
    a = make_pipeline(cfg)
    b = make_pipeline(cfg)  # "restarted process"
    for step in (0, 3, 17):
        np.testing.assert_array_equal(a(step), b(step))


def test_pipeline_host_sharding_disjoint():
    full = make_pipeline(DataConfig(seq_len=16, global_batch=4, n_hosts=1, host_id=0))
    h0 = make_pipeline(DataConfig(seq_len=16, global_batch=4, n_hosts=2, host_id=0))
    h1 = make_pipeline(DataConfig(seq_len=16, global_batch=4, n_hosts=2, host_id=1))
    got = np.concatenate([h0(7), h1(7)])
    np.testing.assert_array_equal(got, full(7))


def test_zipf_corpus_is_learnable_structure():
    """Bigram source: successor entropy << unigram entropy."""
    src = SyntheticZipf(128)
    rng = np.random.default_rng(0)
    seq = src.sample(rng, 4000)
    # empirical conditional diversity
    from collections import defaultdict
    succ = defaultdict(set)
    for a, b in zip(seq[:-1], seq[1:]):
        succ[a].add(b)
    avg_succ = np.mean([len(v) for v in succ.values()])
    assert avg_succ < 32, "bigram structure must be narrow enough to learn"


# ---------------- optimizer ----------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1, total_steps=100,
                      grad_clip=10.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


# ---------------- checkpoint ----------------

def _tree(key):
    return {
        "a": jax.random.normal(key, (8, 4)),
        "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.int32)},
        "tup": (jnp.ones(3), jnp.zeros(2)),
        "none": None,
        "qt": quantize_tensor(jax.random.normal(key, (64, 8)),
                              QuantConfig(bits=2, group_size=32)),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 7, tree)
    restored, manifest = restore_checkpoint(tmp_path)
    assert manifest["step"] == 7
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))
    assert isinstance(restored["tup"], tuple) and len(restored["tup"]) == 2
    assert restored["none"] is None
    np.testing.assert_allclose(np.asarray(restored["qt"].dequantize()),
                               np.asarray(tree["qt"].dequantize()))


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.ones((4,))}
    d = save_checkpoint(tmp_path, 1, tree)
    # flip bytes in the shard
    f = d / "host0000.npz"
    raw = bytearray(f.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        restore_checkpoint(tmp_path, 1)


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"w": jnp.full((4,), float(step))})
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    (tree, manifest) = mgr.restore()
    assert manifest["step"] == 4 and float(tree["w"][0]) == 4.0


# ---------------- fault tolerance ----------------

def test_watchdog_flags_straggler():
    wd = StepWatchdog(threshold=2.0)
    for _ in range(10):
        wd.observe(0.1)
    assert wd.observe(0.5) is True
    assert wd.flagged == 1


def test_run_resilient_recovers_from_failure(tmp_path):
    mgr = CheckpointManager(tmp_path)
    failures = {"armed": True}

    def step_fn(state, step):
        if step == 7 and failures["armed"]:
            failures["armed"] = False
            raise RuntimeError("injected node failure")
        return {"w": state["w"] + 1}

    state, events = run_resilient(step_fn, {"w": jnp.zeros(())}, n_steps=10,
                                  ckpt=mgr, save_every=5)
    kinds = [e[0] for e in events]
    assert "failure" in kinds and "restored" in kinds
    assert float(state["w"]) == 10.0, "deterministic replay must converge to the same state"


def test_remesh_restore(tmp_path):
    from repro.dist.fault import remesh_restore
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, {"w": jnp.arange(8.0)})
    mgr.wait()
    tree, manifest = remesh_restore(mgr, None)
    assert manifest["step"] == 3
    np.testing.assert_allclose(np.asarray(tree["w"]), np.arange(8.0))


# ---------------- compressed collectives ----------------

def test_compressed_psum_single_axis():
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import compressed_psum
    from repro.dist.compat import shard_map  # jax moved/renamed shard_map
    from repro.launch.mesh import make_local_mesh
    import functools

    mesh = make_local_mesh()
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
                       check_vma=False)
    def f(v):
        return compressed_psum(v, "data", bits=8, group=32)

    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=2e-2, atol=2e-2)


def test_compression_error_bound_simulated_shards():
    """N simulated shards: quantize-then-sum error stays within N * scale/2."""
    from repro.core.quant import compute_qparams, quantize_codes, dequantize_codes
    cfg = QuantConfig(bits=8, group_size=64)
    rng = np.random.default_rng(0)
    shards = [jnp.asarray(rng.normal(size=(256, 1)).astype(np.float32)) for _ in range(4)]
    total = sum(np.asarray(s) for s in shards)
    deq_total = np.zeros_like(total)
    max_err_bound = 0.0
    for s in shards:
        sc, z = compute_qparams(s, cfg)
        c = quantize_codes(s, sc, z, cfg)
        deq_total += np.asarray(dequantize_codes(c, sc, z, cfg))
        max_err_bound += float(jnp.max(sc)) * 0.5
    assert np.max(np.abs(deq_total - total)) <= max_err_bound + 1e-6


def test_grad_accumulation_matches_full_batch():
    """accum_steps=4 must produce the same update as the full batch (the
    loss is a mean over tokens and microbatches have equal token counts)."""
    from repro.configs import get_config
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim.adamw import adamw_init, AdamWConfig

    cfg = get_config("opt-tiny").reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab_size=128, n_heads=2, n_kv_heads=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size)}
    p1, _, m1 = jax.jit(make_train_step(cfg, ocfg, accum_steps=1))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, ocfg, accum_steps=4))(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


# ---------------- distributed decode attention ----------------

def test_partial_attention_merge_equals_full_softmax():
    """Simulated 4-shard seq split: partial (m,l,acc) + merge == dense
    softmax attention (the math behind sharded_decode_attention)."""
    from repro.dist.attention import partial_decode_attention, merge_partials
    from repro.kernels.ref import flash_decode_ref
    key = jax.random.PRNGKey(0)
    B, S, H, Dh, n_shards = 2, 128, 4, 16, 4
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, Dh))
    ss = S // n_shards
    parts = [partial_decode_attention(q, k[:, i*ss:(i+1)*ss], v[:, i*ss:(i+1)*ss],
                                      kv_len=100, start=i*ss)
             for i in range(n_shards)]
    out = merge_partials(jnp.stack([p[0] for p in parts]),
                         jnp.stack([p[1] for p in parts]),
                         jnp.stack([p[2] for p in parts]))
    want = flash_decode_ref(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_sharded_decode_attention_shard_map():
    """End-to-end through shard_map on the local mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.attention import sharded_decode_attention
    from repro.dist.compat import shard_map  # jax moved/renamed shard_map
    from repro.kernels.ref import flash_decode_ref
    from repro.launch.mesh import make_local_mesh
    import functools

    mesh = make_local_mesh()
    key = jax.random.PRNGKey(3)
    B, S, H, Dh = 1, 64, 2, 8
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, Dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, Dh))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(None, "data"), P(None, "data")),
                       out_specs=P(), check_vma=False)
    def f(q, ks, vs):
        idx = jax.lax.axis_index("data")
        return sharded_decode_attention(q, ks, vs, "data",
                                        shard_start=idx * ks.shape[1])

    out = f(q, k, v)
    want = flash_decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_restore_returns_jax_arrays(tmp_path):
    """Regression: numpy leaves from restore broke tracer indexing in the
    jitted search (stacked-weight slicing by a traced unit index)."""
    tree = {"w": jnp.arange(12.0).reshape(3, 4)}
    save_checkpoint(tmp_path, 1, tree)
    restored, _ = restore_checkpoint(tmp_path, 1)
    assert isinstance(restored["w"], jax.Array)

    @jax.jit
    def take(i):
        return restored["w"][i]
    np.testing.assert_allclose(np.asarray(take(jnp.int32(1))), [4.0, 5, 6, 7])
