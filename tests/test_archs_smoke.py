"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import init_params, forward, init_cache, decode_step, prefill
from repro.models.frontends import stub_vision_embeds, stub_audio_frames
from repro.optim.adamw import AdamWConfig, adamw_init

ARCHS = list_archs()


def _batch_for(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = stub_vision_embeds(key, cfg, B, cfg.frontend_len)
    if cfg.is_enc_dec:
        batch["enc_embeds"] = stub_audio_frames(key, cfg, B, S)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(hash(arch) % 2 ** 31)
    params = init_params(key, cfg)
    batch = _batch_for(cfg, key)
    kw = {k: v for k, v in batch.items() if k != "tokens"}
    logits = forward(params, cfg, batch["tokens"], **kw)
    B, S = batch["tokens"].shape
    prefix = cfg.frontend_len if cfg.frontend == "vision" else 0
    assert logits.shape == (B, S + prefix, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=10,
                                                    warmup_steps=1)))
    batch = _batch_for(cfg, key)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{arch}: train step did not update params"
    # no NaN anywhere in the updated tree
    for leaf in jax.tree.leaves(params2):
        assert not bool(jnp.any(jnp.isnan(leaf))), f"{arch}: NaN in updated params"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B = 2
    tokens = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, 64)
    if cfg.is_enc_dec:
        enc = stub_audio_frames(key, cfg, B, 16)
        _, cache = prefill(params, cfg, tokens, 64, enc_embeds=enc)
    logits, cache2 = decode_step(params, cfg, tokens, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_param_count_sane():
    """Full configs match their nameplate sizes (±25% — vocab padding, per-
    config approximations)."""
    expect = {
        "internlm2-1.8b": 1.8e9, "qwen3-4b": 4e9, "yi-6b": 6e9,
        "command-r-35b": 35e9, "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        # the ASSIGNED moonshot config (48L x 64e x d_ff 1408) arithmetically
        # holds ~28B total params — more than the 16B nameplate (the real
        # Moonlight has 27 layers); we follow the assignment spec verbatim.
        "moonshot-v1-16b-a3b": 28e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.1f}B"


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.active_param_count()
    assert 4e9 < active < 9e9  # nameplate: 6.6B active
    assert active < cfg.param_count() / 3
