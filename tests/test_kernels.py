"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig, quantize_tensor
from repro.kernels import quant_matmul, group_quant
from repro.kernels.ref import quant_matmul_ref, group_quant_ref
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.group_quant import group_quant_pallas

SHAPES_MM = [(8, 128, 128), (16, 256, 256), (32, 512, 128), (8, 128, 384)]
SHAPES_GQ = [(128, 128), (256, 256), (512, 128), (384, 256)]


@pytest.mark.parametrize("bits,group", [(2, 64), (2, 128), (4, 64), (8, 32), (3, 32)])
@pytest.mark.parametrize("M,K,N", SHAPES_MM)
def test_quant_matmul_sweep(bits, group, M, K, N):
    if K % group:
        pytest.skip("group must divide K")
    key = jax.random.PRNGKey(M * K + N + bits)
    w = jax.random.normal(key, (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=group))
    out = quant_matmul(x, qt.packed, qt.scale, qt.zero, bits=bits, group=group)
    want = quant_matmul_ref(x, qt.packed, qt.scale, qt.zero, bits, group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype):
    bits, group, M, K, N = 2, 64, 8, 128, 128
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K)).astype(dtype)
    qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=group))
    out = quant_matmul(x, qt.packed, qt.scale, qt.zero, bits=bits, group=group)
    want = quant_matmul_ref(x.astype(jnp.float32), qt.packed, qt.scale, qt.zero,
                            bits, group)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=tol, atol=tol)


def test_quant_matmul_fallback_on_odd_shapes():
    """Non-tileable shapes silently use the reference path (still correct)."""
    bits, group = 2, 32
    K, N, M = 96, 100, 7  # N % 128 != 0, M % 8 != 0
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=group))
    out = quant_matmul(x, qt.packed, qt.scale, qt.zero, bits=bits, group=group)
    want = quant_matmul_ref(x, qt.packed, qt.scale, qt.zero, bits, group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def _assert_within_one_step(fq, fqr, scale, group):
    """Reduction-order ULP differences in the scale can flip a round-half
    boundary — allow at most ONE quantization step on <0.1% of elements."""
    fq = np.asarray(fq, dtype=np.float32)
    fqr = np.asarray(fqr, dtype=np.float32)
    step = np.repeat(np.asarray(scale), group, axis=0)
    diff = np.abs(fq - fqr)
    assert np.all(diff <= step * 1.001 + 1e-6), "differs by more than one step"
    frac = float(np.mean(diff > step * 0.5))
    assert frac < 1e-3, f"{frac:.2%} of elements off by a step (expected ~0)"


@pytest.mark.parametrize("bits,group", [(2, 32), (2, 128), (4, 64), (8, 64)])
@pytest.mark.parametrize("K,N", SHAPES_GQ)
def test_group_quant_sweep(bits, group, K, N):
    if K % group:
        pytest.skip("group must divide K")
    key = jax.random.PRNGKey(K + N + bits)
    w = jax.random.normal(key, (K, N)) * 2.5
    fq, s, z = group_quant(w, bits=bits, group=group)
    fqr, sr, zr = group_quant_ref(w, bits, group)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-5, atol=1e-8)
    _assert_within_one_step(fq, fqr, sr, group)


def test_group_quant_bf16():
    w = (jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 2).astype(jnp.bfloat16)
    fq, s, z = group_quant(w, bits=4, group=64)
    fqr, sr, _ = group_quant_ref(w, 4, 64)
    assert fq.dtype == jnp.bfloat16
    _assert_within_one_step(fq, fqr, sr, 64)


def test_pallas_grid_accumulation():
    """K-axis grid accumulation: multiple k-steps must sum correctly."""
    bits, group = 2, 64
    M, K, N = 8, 1024, 128  # K/bk = 2 grid steps at bk=512
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    qt = quantize_tensor(w, QuantConfig(bits=bits, group_size=group))
    out = quant_matmul_pallas(x, qt.packed, qt.scale, qt.zero, bits=bits,
                              group=group, bm=8, bk=512, bn=128, interpret=True)
    want = quant_matmul_ref(x, qt.packed, qt.scale, qt.zero, bits, group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_group_quant_tile_shapes():
    """bg tiling never straddles a group boundary."""
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    for bg in (1, 2, 4):
        fq, s, z = group_quant_pallas(w, bits=2, group=128, bg=bg, bn=128,
                                      interpret=True)
        fqr, sr, _ = group_quant_ref(w, 2, 128)
        np.testing.assert_allclose(np.asarray(fq), np.asarray(fqr), rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# flash_decode: fused single-token decode attention (bf16 + int8 KV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Dh,chunk", [(2, 256, 4, 32, 64), (1, 512, 2, 64, 128),
                                            (2, 128, 8, 16, 128)])
def test_flash_decode_sweep(B, S, H, Dh, chunk):
    from repro.kernels import flash_decode
    from repro.kernels.ref import flash_decode_ref
    key = jax.random.PRNGKey(B + S + H)
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, Dh))
    out = flash_decode(q, k, v, chunk=chunk)
    want = flash_decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_decode_kv_len_mask():
    from repro.kernels import flash_decode
    from repro.kernels.ref import flash_decode_ref
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 16))
    # poison masked region: result must be unaffected
    k2 = k.at[:, 100:].set(50.0)
    v2 = v.at[:, 100:].set(50.0)
    out = flash_decode(q, k2, v2, kv_len=100, chunk=32)
    want = flash_decode_ref(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_decode_int8_cache():
    """int8-quantized KV + per-(pos, head) scales vs explicit-dequant oracle."""
    from repro.kernels import flash_decode
    from repro.kernels.ref import flash_decode_ref
    key = jax.random.PRNGKey(3)
    B, S, H, Dh = 2, 256, 4, 32
    q = jax.random.normal(key, (B, H, Dh))
    kf = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, Dh))
    vf = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, Dh))
    ks = jnp.max(jnp.abs(kf), axis=-1) / 127.0 + 1e-8
    vs = jnp.max(jnp.abs(vf), axis=-1) / 127.0 + 1e-8
    k8 = jnp.round(kf / ks[..., None]).astype(jnp.int8)
    v8 = jnp.round(vf / vs[..., None]).astype(jnp.int8)
    out = flash_decode(q, k8, v8, ks, vs, chunk=64)
    want = flash_decode_ref(q, k8, v8, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)
    # and the int8 path approximates the fp path
    dense = flash_decode_ref(q, kf, vf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# Fused transform+fake-quant (the population search's per-proposal hot path)
# ---------------------------------------------------------------------------

def _random_transform(f, seed=0, identity=False):
    import repro.core.invariance as inv
    if identity:
        t = inv.identity_transform(f)
        return t.pi, t.s, t.phi
    pi = jax.random.permutation(jax.random.PRNGKey(seed), f).astype(jnp.int32)
    s = 1.0 + 0.05 * jax.random.normal(jax.random.PRNGKey(seed + 1), (f,))
    phi = 1e-2 * jax.random.normal(jax.random.PRNGKey(seed + 2), (f // 2,))
    return pi, s, phi


@pytest.mark.parametrize("mode", ["up", "down"])
@pytest.mark.parametrize("bits,group", [(2, 16), (2, 32), (4, 32), (3, 16)])
@pytest.mark.parametrize("D,F", [(64, 128), (128, 64), (96, 96)])
def test_transform_quant_sweep(mode, bits, group, D, F):
    """Fused kernel == materialize-then-quantize oracle to <=1e-5 in
    interpret mode across shapes / group sizes / modes (ISSUE 3 bar)."""
    from repro.kernels import transform_quant
    from repro.kernels.ref import transform_quant_ref
    K = D if mode == "up" else F
    if K % group:
        pytest.skip("group must divide the quant (K) axis")
    shape = (D, F) if mode == "up" else (F, D)
    w = jax.random.normal(jax.random.PRNGKey(D + F + bits), shape)
    f = F
    pi, s, phi = _random_transform(f, seed=bits)
    out = transform_quant(w, pi, s, phi, bits=bits, group=group, mode=mode)
    want = transform_quant_ref(w, pi, s, phi, bits=bits, group=group, mode=mode)
    for o, wt in zip(out, want):
        np.testing.assert_allclose(np.asarray(o), np.asarray(wt),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["up", "down"])
def test_transform_quant_identity_is_plain_fake_quant(mode):
    """Identity (pi, s, phi) must reduce to the plain group fake-quant
    roundtrip — ties the fused kernel to core.quant.fake_quant exactly."""
    from repro.core.quant import fake_quant
    from repro.kernels import transform_quant
    D, F, group = 64, 128, 32
    shape = (D, F) if mode == "up" else (F, D)
    w = jax.random.normal(jax.random.PRNGKey(9), shape)
    pi, s, phi = _random_transform(F, identity=True)
    fq, _, _ = transform_quant(w, pi, s, phi, bits=2, group=group, mode=mode)
    want = fake_quant(w, QuantConfig(bits=2, group_size=group))
    np.testing.assert_allclose(np.asarray(fq), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_transform_quant_matches_apply_transform_ffn():
    """Kernel pair (up, down) == inv.apply_transform_ffn + fake_quant on a
    real FFN weight pair (the exact computation the search engine fuses)."""
    import repro.core.invariance as inv
    from repro.core.quant import fake_quant
    from repro.kernels import transform_quant
    D, F, group = 64, 128, 32
    w_up = jax.random.normal(jax.random.PRNGKey(0), (D, F))
    w_down = jax.random.normal(jax.random.PRNGKey(1), (F, D))
    pi, s, phi = _random_transform(F, seed=42)
    t = inv.FFNTransform(pi=pi, s=s, phi=phi)
    up_t, down_t, _, _, _ = inv.apply_transform_ffn(t, w_up, w_down)
    qcfg = QuantConfig(bits=2, group_size=group)
    got_up = transform_quant(w_up, pi, s, phi, bits=2, group=group, mode="up")[0]
    got_down = transform_quant(w_down, pi, s, phi, bits=2, group=group,
                               mode="down")[0]
    np.testing.assert_allclose(np.asarray(got_up),
                               np.asarray(fake_quant(up_t, qcfg)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_down),
                               np.asarray(fake_quant(down_t, qcfg)),
                               rtol=1e-5, atol=1e-5)


def test_transform_quant_ref_fallback_on_untileable_shapes():
    """A down-mode N that cannot column-tile (192 > 128, not a multiple)
    must silently fall back to the jnp reference — same contract as the
    other ops.py wrappers."""
    from repro.kernels import transform_quant
    from repro.kernels.ref import transform_quant_ref
    F, D, group = 64, 192, 32
    w = jax.random.normal(jax.random.PRNGKey(2), (F, D))
    pi, s, phi = _random_transform(F, seed=3)
    out = transform_quant(w, pi, s, phi, bits=2, group=group, mode="down")
    want = transform_quant_ref(w, pi, s, phi, bits=2, group=group, mode="down")
    for o, wt in zip(out, want):
        np.testing.assert_allclose(np.asarray(o), np.asarray(wt),
                                   rtol=1e-5, atol=1e-5)
