"""Quantization substrate: roundtrip bounds, packing, QTensor, hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import (QuantConfig, compute_qparams, fake_quant,
                              pack_codes, unpack_codes, quantize_tensor,
                              bits_per_param, vals_per_word)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("group", [32, 64])
def test_roundtrip_error_bound(bits, group):
    """|fq(w) - w| <= scale/2 + eps per element (the defining property)."""
    key = jax.random.PRNGKey(bits * 100 + group)
    w = jax.random.normal(key, (128, 16)) * 3.0
    cfg = QuantConfig(bits=bits, group_size=group)
    scale, zero = compute_qparams(w, cfg)
    fq = fake_quant(w, cfg)
    bound = jnp.repeat(scale, group, axis=0) * 0.5 + 1e-5
    assert bool(jnp.all(jnp.abs(fq - w) <= bound)), "roundtrip exceeded scale/2"


def test_extremes_are_exact():
    """Group max and min map (near-)exactly (asymmetric quant covers range)."""
    w = jnp.array([[-1.0], [0.5], [3.0], [-2.0]] * 8)  # (32,1)
    cfg = QuantConfig(bits=2, group_size=32)
    fq = fake_quant(w, cfg)
    scale, _ = compute_qparams(w, cfg)
    assert abs(float(fq.max()) - 3.0) <= float(scale[0, 0]) * 0.5 + 1e-6
    assert abs(float(fq.min()) + 2.0) <= float(scale[0, 0]) * 0.5 + 1e-6


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_pack_unpack_roundtrip(bits):
    key = jax.random.PRNGKey(bits)
    vpw = vals_per_word(bits)
    K = vpw * 6
    codes = jax.random.randint(key, (K, 8), 0, 2 ** bits, dtype=jnp.int32)
    packed = pack_codes(codes, bits)
    assert packed.dtype == jnp.uint32 and packed.shape == (K // vpw, 8)
    out = unpack_codes(packed, bits, K)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@pytest.mark.parametrize("bits,group", [(2, 32), (3, 32), (4, 64), (8, 64)])
def test_qtensor_matches_fake_quant(bits, group):
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (128, 32))
    cfg = QuantConfig(bits=bits, group_size=group)
    qt = quantize_tensor(w, cfg)
    np.testing.assert_allclose(np.asarray(qt.dequantize()),
                               np.asarray(fake_quant(w, cfg)),
                               rtol=1e-5, atol=1e-6)


def test_qtensor_stacked_scan_slice():
    """Stacked QTensor slices correctly under lax.scan (model serving path)."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (4, 64, 32))  # (L, K, N)
    cfg = QuantConfig(bits=2, group_size=32)
    qt = quantize_tensor(w, cfg)
    assert qt.packed.shape[0] == 4 and qt.shape == (64, 32)

    def body(c, qt_l):
        return c + jnp.sum(qt_l.dequantize()), None

    total, _ = jax.lax.scan(body, jnp.float32(0), qt)
    expect = float(jnp.sum(qt.dequantize()))
    assert abs(float(total) - expect) < 1e-2


def test_stacked_fake_quant_equals_per_slice():
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (3, 64, 16))
    cfg = QuantConfig(bits=4, group_size=32)
    stacked = fake_quant(w, cfg)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(stacked[i]),
                                   np.asarray(fake_quant(w[i], cfg)), rtol=1e-6)


def test_bits_per_param_matches_paper():
    # paper Table 3: 2-bit g128 -> 2.125 (code bits + fp16 scale only)
    assert abs(bits_per_param(QuantConfig(bits=2, group_size=128),
                              scale_bits=16, zero_bits=0) - 2.125) < 1e-9
    assert abs(bits_per_param(QuantConfig(bits=2, group_size=64),
                              scale_bits=16, zero_bits=0) - 2.25) < 1e-9
    assert abs(bits_per_param(QuantConfig(bits=3, group_size=128),
                              scale_bits=16, zero_bits=0) - (3.2 + 0.125)) < 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.sampled_from([16, 32, 64]), st.floats(0.1, 50.0))
def test_hypothesis_roundtrip_monotone_in_bits(seed, group, spread):
    """More bits never increases the roundtrip error (system invariant)."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (64, 8)) * spread
    errs = []
    for bits in (2, 4, 8):
        fq = fake_quant(w, QuantConfig(bits=bits, group_size=group))
        errs.append(float(jnp.mean(jnp.abs(fq - w))))
    assert errs[0] >= errs[1] >= errs[2]


def test_constant_group_degenerate_is_finite():
    """A zero-range group cannot be represented exactly under a CLIPPED
    integer zero-point (industry-standard behaviour); it must still be finite
    and bounded by |c|."""
    w = jnp.full((64, 4), 1.234)
    fq = fake_quant(w, QuantConfig(bits=2, group_size=32))
    assert bool(jnp.all(jnp.isfinite(fq)))
    assert float(jnp.max(jnp.abs(fq - w))) <= 1.234 + 1e-6
