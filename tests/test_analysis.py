"""Static-analysis pass: fixture corpus, baseline round-trip, suppression,
and the Pallas-budget <-> runtime-guard regression pin."""
import pathlib

import pytest

from repro.analysis import framework as fw
from repro.analysis.cli import main as analysis_main
from repro.analysis.pallas_budget import zoo_units

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent
FIXTURES = HERE / "fixtures" / "analysis"

EXPECTED_CHECKERS = {"jit-purity", "prng-discipline", "monotonic-clock",
                     "pallas-budget", "metrics-hygiene"}


def run(paths, select=None):
    return fw.run_analysis([str(p) for p in paths], select=select,
                           root=FIXTURES)


def test_registry_has_all_checkers():
    fw._load_default_checkers()
    assert set(fw.CHECKERS) == EXPECTED_CHECKERS
    for c in fw.CHECKERS.values():
        assert c.description and c.bug_class


# (rule, bad fixture, expected finding count, good fixture)
CASES = [
    ("jit-purity", "purity_bad.py", 5, "purity_good.py"),
    ("prng-discipline", "prng_bad.py", 2, "prng_good.py"),
    ("monotonic-clock", "clocks_bad.py", 2, "clocks_good.py"),
    ("pallas-budget", "pallas_bad.py", 3, "pallas_good.py"),
    ("metrics-hygiene", "metrics_bad.py", 3, "metrics_good.py"),
]


@pytest.mark.parametrize("rule,bad,n_bad,good", CASES,
                         ids=[c[0] for c in CASES])
def test_fixture_pair(rule, bad, n_bad, good):
    rep = run([FIXTURES / bad], select=[rule])
    assert len(rep.findings) == n_bad, [f.message for f in rep.findings]
    assert all(f.rule == rule and f.path == bad for f in rep.findings)
    rep = run([FIXTURES / good], select=[rule])
    assert rep.findings == [], [f.message for f in rep.findings]


def test_corpus_full_sweep_counts_by_rule():
    """All checkers over the whole corpus: bad files produce exactly the
    per-rule counts, good files produce nothing (cross-checker silence)."""
    rep = run([FIXTURES])
    by_rule = rep.to_json()["summary"]["by_rule"]
    assert by_rule == {rule: n for rule, _, n, _ in CASES}
    assert not any(f.path.endswith("_good.py") for f in rep.findings)
    assert rep.suppressed == []


def test_finding_messages_name_the_bug():
    rep = run([FIXTURES / "purity_bad.py"], select=["jit-purity"])
    msgs = "\n".join(f.message for f in rep.findings)
    assert "trace-time constant" in msgs
    assert "jax.debug.print" in msgs
    assert "once per compile" in msgs
    assert "lax.cond" in msgs
    rep = run([FIXTURES / "pallas_bad.py"], select=["pallas-budget"])
    msgs = "\n".join(f.message for f in rep.findings)
    assert "_TQ_STRIP_BYTES" in msgs
    assert "not divisible by group" in msgs
    assert "no 128-divisible block" in msgs
    # symbols anchor findings for baseline identity
    rep = run([FIXTURES / "clocks_bad.py"], select=["monotonic-clock"])
    assert sorted(f.symbol for f in rep.findings) == ["bad_alias",
                                                      "bad_direct"]


def test_skip_file_and_inline_suppression(tmp_path):
    bad = ("import time\n\n\n"
           "def f():\n"
           "    t0 = time.time()\n"
           "    return time.time() - t0\n")
    mod = tmp_path / "mod.py"
    mod.write_text("# analysis: skip-file\n" + bad)
    rep = fw.run_analysis([str(mod)], root=tmp_path)
    assert rep.findings == [] and rep.files == []
    mod.write_text(bad.replace(
        "return time.time() - t0",
        "return time.time() - t0  # analysis: ignore[monotonic-clock]"))
    rep = fw.run_analysis([str(mod)], root=tmp_path)
    assert rep.findings == []
    assert [f.rule for f in rep.suppressed] == ["monotonic-clock"]
    # bare `ignore` (no rule list) silences every rule on the line
    mod.write_text(bad.replace(
        "return time.time() - t0",
        "return time.time() - t0  # analysis: ignore"))
    rep = fw.run_analysis([str(mod)], root=tmp_path)
    assert rep.findings == [] and len(rep.suppressed) == 1


def test_cli_baseline_roundtrip(tmp_path):
    """add -> accept via --update-baseline -> clean; new finding fails;
    suppressing the new finding passes again."""
    mod = tmp_path / "mod.py"
    mod.write_text("import time\n\n\n"
                   "def f():\n"
                   "    t0 = time.time()\n"
                   "    return time.time() - t0\n")
    base = tmp_path / "baseline.json"
    argv = [str(mod), "--baseline", str(base), "--root", str(tmp_path)]
    assert analysis_main(argv) == 1               # unbaselined: gate fails
    assert analysis_main(argv + ["--update-baseline"]) == 0
    assert analysis_main(argv) == 0               # accepted: gate passes
    mod.write_text(mod.read_text() +
                   "\n\ndef g():\n"
                   "    t1 = time.time()\n"
                   "    return time.time() - t1\n")
    assert analysis_main(argv) == 1               # only the NEW one fails
    mod.write_text(mod.read_text().replace(
        "return time.time() - t1",
        "return time.time() - t1  # analysis: ignore[monotonic-clock]"))
    assert analysis_main(argv) == 0


def test_baseline_survives_line_churn(tmp_path):
    """Identity is (rule, path, symbol, message): edits above a baselined
    finding must not trip the gate even though its line moved."""
    mod = tmp_path / "mod.py"
    body = ("import time\n\n\n"
            "def f():\n"
            "    t0 = time.time()\n"
            "    return time.time() - t0\n")
    mod.write_text(body)
    base = tmp_path / "baseline.json"
    argv = [str(mod), "--baseline", str(base), "--root", str(tmp_path)]
    assert analysis_main(argv + ["--update-baseline"]) == 0
    mod.write_text("# a comment pushing every line down\n\n\n" + body)
    assert analysis_main(argv) == 0


def test_cli_json_report_shape(tmp_path):
    import json
    mod = tmp_path / "mod.py"
    mod.write_text("import time\n\n\n"
                   "def f():\n"
                   "    t0 = time.time()\n"
                   "    return time.time() - t0\n")
    out = tmp_path / "report.json"
    rc = analysis_main([str(mod), "--baseline", "", "--root", str(tmp_path),
                        "--json", str(out)])
    assert rc == 1
    rep = json.loads(out.read_text())
    assert rep["version"] == fw.BASELINE_VERSION
    assert rep["tool"] == "repro.analysis"
    assert set(rep["checkers"]) == EXPECTED_CHECKERS
    assert rep["summary"]["total"] == len(rep["findings"]) == 1
    assert rep["summary"]["new"] == 1
    assert rep["summary"]["by_rule"] == {"monotonic-clock": 1}
    f = rep["findings"][0]
    assert f["path"] == "mod.py" and f["symbol"] == "f" and f["line"] == 6


def test_cli_select_unknown_checker_is_usage_error(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text("x = 1\n")
    rc = analysis_main([str(mod), "--root", str(tmp_path),
                        "--select", "no-such-checker"])
    assert rc == 2


def test_pallas_budget_matches_runtime_guard():
    """The lint-time verdict IS the runtime fallback decision: zoo_units()
    must agree with ops.tq_plan for every (arch, projection) unit, and the
    abstract eval through the real wrapper must hold the (K, N) contract."""
    from repro.configs import get_config, list_archs
    from repro.kernels import ops

    rows = zoo_units()
    archs = list_archs() + ["opt-1.3b"]
    assert sorted({r["arch"] for r in rows}) == sorted(set(archs))
    n_ffn = sum(1 for a in archs if get_config(a).d_ff)
    checked = 0
    for r in rows:
        if r["proj"] is None:
            continue  # pure-SSM arch: nothing to transform
        plan = ops.tq_plan(r["K"], r["N"], group=r["group"], mode=r["mode"])
        assert r["ok"] == plan.ok
        assert r["strip_bytes"] == plan.strip_bytes
        if plan.ok:
            assert plan.strip_bytes <= ops._TQ_STRIP_BYTES
        else:
            assert r["reason"]
        assert r["eval_shape"] is not None, "abstract eval must run under jax"
        assert r["eval_shape"][0] == (r["K"], r["N"])
        checked += 1
    assert checked == 2 * n_ffn  # both projections of every FFN-bearing arch


def test_committed_baseline_covers_zoo_fallbacks():
    """Every not-ok zoo unit is a baselined pallas-budget finding (and
    nothing else is): the committed baseline tracks the real fallback set."""
    base = fw.load_baseline(REPO / "analysis_baseline.json")
    pallas = [f for f in base if f.rule == "pallas-budget"]
    bad_rows = [r for r in zoo_units() if r["proj"] and not r["ok"]]
    assert len(pallas) == len(bad_rows) > 0
    msgs = "\n".join(f.message for f in pallas)
    for r in bad_rows:
        assert f"config {r['arch']} ffn_{r['proj']} " in msgs, r["arch"]


def test_repo_src_is_clean_against_committed_baseline():
    """The CI gate itself: zero non-baselined findings on the tree."""
    rc = analysis_main([str(REPO / "src"), "--baseline",
                        str(REPO / "analysis_baseline.json"),
                        "--root", str(REPO)])
    assert rc == 0
